"""Workload ingestion layer: weighted task graphs with provenance.

Every workload that enters the mapper — synthetic benchmark families,
logical mesh communication graphs, HLO-extracted model graphs — is first
expressed as a :class:`TaskGraph`: an UNDIRECTED weighted edge list plus
vertex weights, normalized to one canonical form. This is the single choke
point where

* validation happens (``validate_request``-grade checks: vertex ids in
  range, finite non-negative weights, non-empty graph) with clear
  ``ValueError``s at construction time instead of scheduler-thread errors;
* normalization happens (self-loops dropped, duplicate edges coalesced by
  summing, direction canonicalized to ``u < v``, edges sorted
  lexicographically) so two descriptions of the same workload are the same
  object bit-for-bit;
* weight quantization happens (vertex ids to i32 — guarded by
  :func:`core.graph.check_i32_range` — edge/vertex weights to f32, the
  dtypes the whole device pipeline runs on);
* the stable content fingerprint is derived (:meth:`TaskGraph.fingerprint`,
  blake2b over the canonical arrays) — deterministic across processes, so
  the serving tier's content-addressed cache and durable store can key on
  it directly.

``to_graph()`` produces the canonical padded-CSR :class:`core.graph.Graph`
the partitioning kernels consume; because normalization is canonical, the
CSR (and therefore every downstream mapping) is a pure function of the
fingerprint.

Builders
--------
* :func:`TaskGraph.from_edges` — undirected edge list (each edge once).
* :func:`TaskGraph.from_coo`   — directed COO triples; the undirected
  weight of ``{u, v}`` is the SUM of both directed entries (communication
  volume either direction).
* :func:`TaskGraph.from_graph` — lossless import of an existing padded-CSR
  ``Graph`` (each undirected edge is stored twice with equal weight; the
  ``u < v`` copy is taken).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Mapping

import numpy as np

from . import graph as G

_FP_VERSION = b"TGF1"  # bump when the canonical form changes


def _as_1d(name: str, a, dtype) -> np.ndarray:
    arr = np.asarray(a, dtype)
    if arr.ndim != 1:
        raise ValueError(f"{name} must be 1-D, got shape {arr.shape}")
    return arr


def _canonicalize(n: int, u: np.ndarray, v: np.ndarray,
                  w: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Drop self-loops, canonicalize direction to u < v, coalesce duplicate
    edges by summing their weights, drop non-positive weights, sort
    lexicographically by (u, v). Pure numpy, deterministic."""
    keep = (u != v) & (w > 0.0)
    u, v, w = u[keep], v[keep], w[keep]
    lo = np.minimum(u, v)
    hi = np.maximum(u, v)
    # coalesce: sum weights of identical unordered pairs. np.add.at into a
    # dict-free dense bincount over pair keys would need n^2; sort instead.
    order = np.lexsort((hi, lo))
    lo, hi, w = lo[order], hi[order], w[order]
    if lo.size:
        new_edge = np.ones(lo.size, bool)
        new_edge[1:] = (lo[1:] != lo[:-1]) | (hi[1:] != hi[:-1])
        idx = np.cumsum(new_edge) - 1
        wsum = np.zeros(int(idx[-1]) + 1, np.float64)
        np.add.at(wsum, idx, w)
        lo, hi = lo[new_edge], hi[new_edge]
        w = wsum
    return lo, hi, w


@dataclasses.dataclass(frozen=True, eq=False)
class TaskGraph:
    """Canonical weighted task graph (workload-ingestion currency).

    Fields are the NORMALIZED arrays (see module docstring); construct via
    the ``from_*`` builders, which validate and normalize — the raw
    constructor trusts its inputs and is for internal use.

    ``meta`` carries provenance (where the workload came from: generator
    name + seed, HLO entry computation, mesh axes …). It never enters the
    fingerprint: two identically-shaped workloads from different sources
    are the SAME cacheable content.
    """

    n: int                    # number of tasks (vertices)
    u: np.ndarray             # [m] i32, u < v, lexicographically sorted
    v: np.ndarray             # [m] i32
    w: np.ndarray             # [m] f32 edge weights (communication volume)
    vwgt: np.ndarray          # [n] f32 vertex weights (compute load)
    meta: dict = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------- builders

    @staticmethod
    def from_edges(n: int, u, v, w=None, vwgt=None,
                   meta: Mapping | None = None) -> "TaskGraph":
        """Build from an undirected edge list (each edge listed once;
        duplicates and self-loops are normalized away)."""
        n = int(n)
        if n <= 0:
            raise ValueError(f"task graph needs n >= 1 vertices, got n={n}")
        u = _as_1d("u", u, np.int64)
        v = _as_1d("v", v, np.int64)
        if u.shape != v.shape:
            raise ValueError(f"u and v differ in length: {u.size} vs {v.size}")
        if w is None:
            w = np.ones(u.size, np.float64)
        else:
            w = _as_1d("w", w, np.float64)
            if w.shape != u.shape:
                raise ValueError(
                    f"w length {w.size} does not match edge count {u.size}")
        if u.size and (int(min(u.min(), v.min())) < 0
                       or int(max(u.max(), v.max())) >= n):
            raise ValueError(
                f"edge endpoints out of range [0, {n}): "
                f"min={min(u.min(), v.min())}, max={max(u.max(), v.max())}")
        if not np.all(np.isfinite(w)):
            raise ValueError("edge weights must be finite (found NaN/inf)")
        if np.any(w < 0):
            raise ValueError("edge weights must be non-negative")
        if vwgt is None:
            vw = np.ones(n, np.float64)
        else:
            vw = _as_1d("vwgt", vwgt, np.float64)
            if vw.size != n:
                raise ValueError(
                    f"vwgt length {vw.size} does not match n={n}")
            if not np.all(np.isfinite(vw)):
                raise ValueError("vertex weights must be finite")
            if np.any(vw < 0):
                raise ValueError("vertex weights must be non-negative")
        lo, hi, ww = _canonicalize(n, u, v, w)
        G.check_i32_range(n, 2 * lo.size)  # to_graph stores each edge twice
        return TaskGraph(n=n, u=lo.astype(np.int32), v=hi.astype(np.int32),
                         w=ww.astype(np.float32), vwgt=vw.astype(np.float32),
                         meta=dict(meta or {}))

    @staticmethod
    def from_coo(n: int, rows, cols, vals=None, vwgt=None,
                 meta: Mapping | None = None) -> "TaskGraph":
        """Build from DIRECTED COO triples (e.g. an adjacency / traffic
        matrix in sparse form). The undirected weight of ``{u, v}`` is the
        sum of the ``u->v`` and ``v->u`` entries — total volume crossing
        the pair either direction. Symmetrization is therefore implicit in
        the coalescing step."""
        return TaskGraph.from_edges(n, rows, cols, vals, vwgt=vwgt, meta=meta)

    @staticmethod
    def from_graph(g: G.Graph, meta: Mapping | None = None) -> "TaskGraph":
        """Import a padded-CSR :class:`core.graph.Graph`. The CSR stores
        each undirected edge twice with equal weight; the ``u < v`` copies
        are taken verbatim, so the import is exact (no /2 rounding)."""
        n = int(g.n)
        m = int(g.m)
        rows = np.asarray(g.rows)[:m].astype(np.int64)
        cols = np.asarray(g.cols)[:m].astype(np.int64)
        ew = np.asarray(g.ewgt)[:m].astype(np.float64)
        keep = rows < cols
        return TaskGraph.from_edges(
            n, rows[keep], cols[keep], ew[keep],
            vwgt=np.asarray(g.vwgt)[:n], meta=meta)

    # ------------------------------------------------------------ derived

    @property
    def m(self) -> int:
        """Number of undirected edges (after normalization)."""
        return int(self.u.size)

    def total_edge_weight(self) -> float:
        return float(self.w.sum())

    def total_vertex_weight(self) -> float:
        return float(self.vwgt.sum())

    def fingerprint(self) -> bytes:
        """16-byte stable content address of the canonical arrays.

        blake2b over the little-endian bytes of (n, u, v, w, vwgt) plus a
        format-version tag. Deterministic across processes and platforms
        (the arrays are fixed-dtype and canonically ordered); independent
        of ``meta`` and of the edge order/direction the builder was fed.
        """
        hs = hashlib.blake2b(digest_size=16)
        hs.update(_FP_VERSION)
        hs.update(int(self.n).to_bytes(8, "little"))
        for arr in (self.u, self.v, self.w, self.vwgt):
            a = np.ascontiguousarray(arr)
            if a.dtype.byteorder == ">":  # canonical little-endian bytes
                a = a.astype(a.dtype.newbyteorder("<"))
            hs.update(str(a.dtype).encode())
            hs.update(a.tobytes())
        return hs.digest()

    def to_graph(self, N: int | None = None, M: int | None = None) -> G.Graph:
        """The canonical padded-CSR :class:`core.graph.Graph` (cached for
        the default padding). A pure function of the canonical arrays, so
        equal fingerprints give bitwise-equal CSR graphs."""
        if N is None and M is None:
            cached = _GRAPH_MEMO.get(id(self))
            if cached is not None and cached[0] is self:
                return cached[1]
        g = G.from_edges(self.n, self.u.astype(np.int64),
                         self.v.astype(np.int64),
                         self.w.astype(np.float64), vwgt=self.vwgt,
                         N=N, M=M)
        if N is None and M is None:
            _GRAPH_MEMO[id(self)] = (self, g)
        return g

    def __repr__(self) -> str:  # arrays elided: keep service logs readable
        src = self.meta.get("source", "?")
        return (f"TaskGraph(n={self.n}, m={self.m}, "
                f"source={src!r}, fp={self.fingerprint().hex()[:8]})")


# to_graph memo: keyed by id() with an identity check (a frozen dataclass
# holding arrays cannot be hashed by value; the strong ref in the value
# keeps the association alive and exact).
_GRAPH_MEMO: dict[int, tuple[TaskGraph, G.Graph]] = {}
