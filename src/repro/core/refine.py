"""Refinement: balance-constrained label-propagation (parallel FM analogue).

Per round, every vertex computes its connectivity to all k blocks in one
sparse pass, proposes the best positive-gain move that respects capacity,
and an admission filter caps inflow per target block at its remaining
capacity. A hash-coloring alternation damps oscillation. A separate forced
`rebalance` pass repairs over-capacity blocks at minimal edge-cut loss
(used after uncoarsening projections).

Backends
--------
The per-round (conn, best, gain) computation has two interchangeable
implementations, selected per call via ``backend=``:

* ``"xla"``  — the original path: a ``segment_sum`` scatter over the
  ``g.rows * k + pcols`` flattened index (O(M) random scatter) and a global
  ``argsort`` + cumsum-prefix admission filter.
* ``"ell"``  — the kernel path: the CSR arrays are reshaped once per call
  into a padded ``[N, DEG]`` ELL adjacency (``graph.ell_adjacency``; DEG is
  the static ``graph.default_ell_deg(N, M)`` cap) and per-round
  connectivity comes from ``kernels.ops.lp_gain`` — the Pallas
  ``lp_gain_pallas`` kernel on TPU, its jnp oracle elsewhere. Admission
  replaces the global argsort with per-block *gain-threshold bisection*
  (``_admit_by_threshold``): ~16 masked segment-sums find, independently
  per target block, the smallest gain cutoff whose admitted inflow fits the
  remaining capacity — O(it·N) work, no sort, no [N, k] cumsum tensor.

  Degree-cap policy: vertices whose degree exceeds DEG (``overflow`` rows)
  have truncated ELL connectivity. `lp_refine` FREEZES them — they are
  excluded from the move candidates, so a truncated gain estimate can
  never admit a cut-worsening move (their neighbours still see them
  through their own rows). `rebalance` keeps them movable with the
  truncated conn: balance feasibility depends only on the exact
  weight/capacity bookkeeping, so forced draining still converges — only
  the min-loss ORDERING is approximate on overflow rows. Both policies
  are branch-free on purpose: a ``lax.cond`` guard would lower to
  ``select`` under ``vmap`` (the bucket/layer batched path) and execute
  the dense scatter AND the kernel every round. On the paper's mesh
  families no row overflows and both passes are exact.

  Ties in the threshold bisection are split by a deterministic per-vertex
  hash jitter (relative magnitude 1e-3) so a tie group larger than the
  remaining capacity is admitted partially, like the argsort prefix,
  instead of being rejected wholesale.
* ``"auto"`` — ``"ell"`` when the Pallas kernels are live
  (``kernels.ops.kernel_backend() != "xla"``, i.e. on TPU or when forced
  via ``REPRO_KERNEL_BACKEND``), else ``"xla"``. Resolution happens at
  trace time: flipping ``REPRO_KERNEL_BACKEND`` mid-process does not
  invalidate programs already compiled under ``backend="auto"``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .graph import (Graph, block_weights, default_ell_deg, edge_mask,
                    ell_adjacency, vertex_mask)
from ..kernels import ops as kops

_NEG = -1e30
_THRESHOLD_ITERS = 24   # bisection resolution: max_gain * 2^-24
_TIE_JITTER = 1e-3      # relative per-vertex jitter splitting gain ties


def _vhash(n: int, salt) -> jax.Array:
    s = jnp.asarray(salt).astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
    x = jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(2654435761) ^ s
    x = (x ^ (x >> 15)) * jnp.uint32(0x2C1B3C6D)
    return x ^ (x >> 12)


def resolve_backend(backend: str) -> str:
    if backend == "auto":
        return "ell" if kops.kernel_backend() != "xla" else "xla"
    if backend not in ("ell", "xla"):
        raise ValueError(f"unknown refine backend {backend!r}")
    return backend


def connectivity(g: Graph, part: jax.Array, k: int) -> jax.Array:
    """conn[u, b] = summed weight of edges from u into block b.  [N, k]."""
    emask = edge_mask(g)
    pcols = jnp.where(emask, part[g.cols], 0)
    flat = g.rows * k + pcols
    w = jnp.where(emask, g.ewgt, 0.0)
    return jax.ops.segment_sum(w, flat, num_segments=g.N * k).reshape(g.N, k)


def _make_conn_of(g: Graph, k: int, backend: str, ell_deg: int | None):
    """Per-round connectivity closure for the resolved backend.

    Returns ``(conn_of, overflow)``. ``"ell"`` builds the padded [N, DEG]
    adjacency once per call and routes rounds through
    ``kernels.ops.lp_gain`` (the Pallas kernel on TPU); rows flagged in
    ``overflow`` carry TRUNCATED connectivity — callers choose the policy
    (see module docstring). Deliberately branch-free: no ``lax.cond`` on
    the overflow mask, which would lower to ``select`` under ``vmap`` and
    execute both the dense scatter and the kernel. Only the kernel's conn
    output is consumed — best and gain are recomputed under the caller's
    capacity mask.

    ``ell_deg`` is the static degree cap. Callers that know the REAL
    vertex/edge counts (the multisection driver, ``partition_host``)
    should pass one derived from them: the in-jit fallback
    ``default_ell_deg(N, M)`` sees only the padded shapes, and pow2
    padding skews the mean-degree estimate by up to 2x either way.
    """
    if backend != "ell":
        return (lambda part: connectivity(g, part, k)), jnp.zeros((g.N,), bool)
    deg = ell_deg if ell_deg is not None else default_ell_deg(g.N, g.M)
    adj, adw, overflow = ell_adjacency(g, deg)
    return (lambda part: kops.lp_gain(adj, adw, part, k)[0]), overflow


def _admit_by_threshold(cand, best, gbest, vw, cap, k: int, tiebreak,
                        iters: int = _THRESHOLD_ITERS) -> jax.Array:
    """Per-block gain-threshold admission (the argsort-free prefix filter).

    For each target block b, bisect the smallest threshold t_b such that
    the total vertex weight of candidates with ``gbest >= t_b`` targeting b
    fits in ``cap[b]``; admit exactly those. Monotonicity of inflow in t
    makes the bisection exact up to float resolution; the invariant
    ``inflow(hi) <= cap`` holds throughout, so the admitted set always
    respects capacity. ``tiebreak`` ([N] in [0, 1)) perturbs each positive
    gain by a relative ``_TIE_JITTER`` so equal-gain groups admit a partial
    prefix (in hash order) rather than all-or-nothing.
    """
    gbest = gbest * (1.0 + _TIE_JITTER * tiebreak)
    safe_best = jnp.where(cand, best, 0)
    w_cand = jnp.where(cand, vw, 0.0)
    cap = jnp.maximum(cap, 0.0)

    def inflow(t):
        acc = cand & (gbest >= t[safe_best])
        return jax.ops.segment_sum(jnp.where(acc, w_cand, 0.0), safe_best,
                                   num_segments=k)

    hi0 = jnp.max(jnp.where(cand, gbest, 0.0)) + 1.0
    lo = jnp.zeros((k,), jnp.float32)
    hi = jnp.full((k,), hi0, jnp.float32)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        ok = inflow(mid) <= cap
        return jnp.where(ok, lo, mid), jnp.where(ok, mid, hi)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    t = jnp.where(inflow(jnp.zeros((k,), jnp.float32)) <= cap, 0.0, hi)
    return cand & (gbest >= t[safe_best])


def _admit_by_argsort(cand, best, gbest, vw, cap, k: int, N: int) -> jax.Array:
    """The original global gain-ranked capacity prefix (xla backend)."""
    order = jnp.argsort(jnp.where(cand, -gbest, jnp.inf), stable=True)
    tgt_s = best[order]
    cand_s = cand[order]
    w_s = jnp.where(cand_s, vw[order], 0.0)
    inflow = jnp.cumsum(jax.nn.one_hot(tgt_s, k, dtype=jnp.float32) * w_s[:, None], axis=0)
    ok_s = cand_s & (
        jnp.take_along_axis(inflow, tgt_s[:, None], axis=1)[:, 0]
        <= jnp.maximum(cap[tgt_s], 0.0)
    )
    return jnp.zeros((N,), bool).at[order].set(ok_s)


@functools.partial(jax.jit, static_argnames=("k", "rounds", "backend", "ell_deg"))
def lp_refine(
    g: Graph,
    part: jax.Array,
    k: int,
    Lmax: jax.Array,
    rounds: int = 4,
    salt: int = 0,
    backend: str = "auto",
    ell_deg: int | None = None,
) -> jax.Array:
    """Gain-positive, capacity-respecting label propagation refinement."""
    backend = resolve_backend(backend)
    N = g.N
    vmask = vertex_mask(g)
    h = _vhash(N, salt)
    tiebreak = (h & jnp.uint32(0xFFFF)).astype(jnp.float32) / float(1 << 16)

    conn_of, overflow = _make_conn_of(g, k, backend, ell_deg)
    movable = vmask & ~overflow  # freeze truncated rows (degree-cap policy)

    def one_round(r, part):
        conn = conn_of(part)
        W = block_weights(g, part, k)
        cur_conn = jnp.take_along_axis(conn, part[:, None], axis=1)[:, 0]
        gain = conn - cur_conn[:, None]
        own = jax.nn.one_hot(part, k, dtype=bool)
        fits = (W[None, :] + g.vwgt[:, None]) <= Lmax
        cand_gain = jnp.where(fits & ~own, gain, _NEG)
        best = jnp.argmax(cand_gain, axis=1).astype(jnp.int32)
        gbest = jnp.max(cand_gain, axis=1)
        color = ((h + jnp.uint32(r)) & jnp.uint32(1)) == 0
        cand = movable & (gbest > 0.0) & color
        cap = Lmax - W
        if backend == "ell":
            accept = _admit_by_threshold(cand, best, gbest, g.vwgt, cap, k, tiebreak)
        else:
            accept = _admit_by_argsort(cand, best, gbest, g.vwgt, cap, k, N)
        return jnp.where(accept, best, part)

    return jax.lax.fori_loop(0, rounds, one_round, part)


@functools.partial(jax.jit, static_argnames=("k", "rounds", "backend", "ell_deg"))
def rebalance(
    g: Graph,
    part: jax.Array,
    k: int,
    Lmax: jax.Array,
    rounds: int = 8,
    salt: int = 1,
    backend: str = "auto",
    ell_deg: int | None = None,
) -> jax.Array:
    """Force epsilon-balance: drain over-capacity blocks via min-loss moves.

    With ``backend="ell"`` connectivity comes from the lp_gain kernel.
    Overflow rows stay MOVABLE on truncated conn — balance feasibility
    rests on the exact weight/capacity bookkeeping, truncation only
    perturbs the min-loss ordering for those rows (see module docstring).
    The min-loss argsort admission is kept (it only bites on over-capacity
    rounds).
    """
    backend = resolve_backend(backend)
    N = g.N
    vmask = vertex_mask(g)
    conn_of, _ = _make_conn_of(g, k, backend, ell_deg)

    def one_round(r, part):
        conn = conn_of(part)
        W = block_weights(g, part, k)
        overflow_w = jnp.maximum(W - Lmax, 0.0)  # [k]
        cur_conn = jnp.take_along_axis(conn, part[:, None], axis=1)[:, 0]
        loss = cur_conn[:, None] - conn  # cost of moving u -> b
        own = jax.nn.one_hot(part, k, dtype=bool)
        fits = (W[None, :] + g.vwgt[:, None]) <= Lmax
        cand_loss = jnp.where(fits & ~own, loss, jnp.inf)
        tgt = jnp.argmin(cand_loss, axis=1).astype(jnp.int32)
        lbest = jnp.min(cand_loss, axis=1)
        src_over = overflow_w[part] > 0.0
        cand = vmask & src_over & jnp.isfinite(lbest) & (g.vwgt > 0.0)
        order = jnp.argsort(jnp.where(cand, lbest, jnp.inf), stable=True)
        src_s = part[order]
        tgt_s = tgt[order]
        cand_s = cand[order]
        w_s = jnp.where(cand_s, g.vwgt[order], 0.0)
        outflow = jnp.cumsum(jax.nn.one_hot(src_s, k, dtype=jnp.float32) * w_s[:, None], axis=0)
        inflow = jnp.cumsum(jax.nn.one_hot(tgt_s, k, dtype=jnp.float32) * w_s[:, None], axis=0)
        # drain only what is needed (allow the boundary-crossing move), fill
        # targets only up to capacity.
        out_ok = (jnp.take_along_axis(outflow, src_s[:, None], axis=1)[:, 0] - w_s) < overflow_w[src_s]
        in_ok = jnp.take_along_axis(inflow, tgt_s[:, None], axis=1)[:, 0] <= jnp.maximum(Lmax - W, 0.0)[tgt_s]
        ok_s = cand_s & out_ok & in_ok
        accept = jnp.zeros((N,), bool).at[order].set(ok_s)
        return jnp.where(accept, tgt, part)

    return jax.lax.fori_loop(0, rounds, one_round, part)


def is_balanced(g: Graph, part: jax.Array, k: int, Lmax) -> jax.Array:
    return jnp.all(block_weights(g, part, k) <= Lmax + 1e-6)
