"""Refinement: balance-constrained label-propagation (parallel FM analogue).

Per round, every vertex computes its connectivity to all k blocks in one
sparse pass, proposes the best positive-gain move that respects capacity,
and a global gain-ranked prefix filter admits moves per target block up to
its remaining capacity. A hash-coloring alternation damps oscillation.
A separate forced `rebalance` pass repairs over-capacity blocks at minimal
edge-cut loss (used after uncoarsening projections).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .graph import Graph, block_weights, edge_mask, vertex_mask

_NEG = -1e30


def _vhash(n: int, salt) -> jax.Array:
    s = jnp.asarray(salt).astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
    x = jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(2654435761) ^ s
    x = (x ^ (x >> 15)) * jnp.uint32(0x2C1B3C6D)
    return x ^ (x >> 12)


def connectivity(g: Graph, part: jax.Array, k: int) -> jax.Array:
    """conn[u, b] = summed weight of edges from u into block b.  [N, k]."""
    emask = edge_mask(g)
    pcols = jnp.where(emask, part[g.cols], 0)
    flat = g.rows * k + pcols
    w = jnp.where(emask, g.ewgt, 0.0)
    return jax.ops.segment_sum(w, flat, num_segments=g.N * k).reshape(g.N, k)


@functools.partial(jax.jit, static_argnames=("k", "rounds"))
def lp_refine(
    g: Graph,
    part: jax.Array,
    k: int,
    Lmax: jax.Array,
    rounds: int = 4,
    salt: int = 0,
) -> jax.Array:
    """Gain-positive, capacity-respecting label propagation refinement."""
    N = g.N
    idx = jnp.arange(N, dtype=jnp.int32)
    vmask = vertex_mask(g)
    h = _vhash(N, salt)

    def one_round(r, part):
        conn = connectivity(g, part, k)
        W = block_weights(g, part, k)
        cur_conn = jnp.take_along_axis(conn, part[:, None], axis=1)[:, 0]
        gain = conn - cur_conn[:, None]
        own = jax.nn.one_hot(part, k, dtype=bool)
        fits = (W[None, :] + g.vwgt[:, None]) <= Lmax
        cand_gain = jnp.where(fits & ~own, gain, _NEG)
        best = jnp.argmax(cand_gain, axis=1).astype(jnp.int32)
        gbest = jnp.max(cand_gain, axis=1)
        color = ((h + jnp.uint32(r)) & jnp.uint32(1)) == 0
        cand = vmask & (gbest > 0.0) & color
        # gain-ranked capacity prefix per target block
        order = jnp.argsort(jnp.where(cand, -gbest, jnp.inf), stable=True)
        tgt_s = best[order]
        cand_s = cand[order]
        w_s = jnp.where(cand_s, g.vwgt[order], 0.0)
        inflow = jnp.cumsum(jax.nn.one_hot(tgt_s, k, dtype=jnp.float32) * w_s[:, None], axis=0)
        cap = Lmax - W
        ok_s = cand_s & (jnp.take_along_axis(inflow, tgt_s[:, None], axis=1)[:, 0] <= jnp.maximum(cap[tgt_s], 0.0))
        accept = jnp.zeros((N,), bool).at[order].set(ok_s)
        return jnp.where(accept, best, part)

    return jax.lax.fori_loop(0, rounds, one_round, part)


@functools.partial(jax.jit, static_argnames=("k", "rounds"))
def rebalance(
    g: Graph,
    part: jax.Array,
    k: int,
    Lmax: jax.Array,
    rounds: int = 8,
    salt: int = 1,
) -> jax.Array:
    """Force epsilon-balance: drain over-capacity blocks via min-loss moves."""
    N = g.N
    vmask = vertex_mask(g)

    def one_round(r, part):
        conn = connectivity(g, part, k)
        W = block_weights(g, part, k)
        overflow = jnp.maximum(W - Lmax, 0.0)  # [k]
        cur_conn = jnp.take_along_axis(conn, part[:, None], axis=1)[:, 0]
        loss = cur_conn[:, None] - conn  # cost of moving u -> b
        own = jax.nn.one_hot(part, k, dtype=bool)
        fits = (W[None, :] + g.vwgt[:, None]) <= Lmax
        cand_loss = jnp.where(fits & ~own, loss, jnp.inf)
        tgt = jnp.argmin(cand_loss, axis=1).astype(jnp.int32)
        lbest = jnp.min(cand_loss, axis=1)
        src_over = overflow[part] > 0.0
        cand = vmask & src_over & jnp.isfinite(lbest) & (g.vwgt > 0.0)
        order = jnp.argsort(jnp.where(cand, lbest, jnp.inf), stable=True)
        src_s = part[order]
        tgt_s = tgt[order]
        cand_s = cand[order]
        w_s = jnp.where(cand_s, g.vwgt[order], 0.0)
        outflow = jnp.cumsum(jax.nn.one_hot(src_s, k, dtype=jnp.float32) * w_s[:, None], axis=0)
        inflow = jnp.cumsum(jax.nn.one_hot(tgt_s, k, dtype=jnp.float32) * w_s[:, None], axis=0)
        # drain only what is needed (allow the boundary-crossing move), fill
        # targets only up to capacity.
        out_ok = (jnp.take_along_axis(outflow, src_s[:, None], axis=1)[:, 0] - w_s) < overflow[src_s]
        in_ok = jnp.take_along_axis(inflow, tgt_s[:, None], axis=1)[:, 0] <= jnp.maximum(Lmax - W, 0.0)[tgt_s]
        ok_s = cand_s & out_ok & in_ok
        accept = jnp.zeros((N,), bool).at[order].set(ok_s)
        return jnp.where(accept, tgt, part)

    return jax.lax.fori_loop(0, rounds, one_round, part)


def is_balanced(g: Graph, part: jax.Array, k: int, Lmax) -> jax.Array:
    return jnp.all(block_weights(g, part, k) <= Lmax + 1e-6)
