"""Coarsening: heavy-edge matching (HEM) + contraction, fully vectorized.

Matching uses multi-round handshaking: every unmatched vertex proposes to
its heaviest unmatched neighbour (deterministic jittered tie-breaks, the
jitter re-salted per round so tie-locked configurations break up); mutual
proposals are contracted. This is the standard shared-memory parallel HEM
(cf. Mt-Metis / Mt-KaHyPar coarsening) re-expressed over static-shape
arrays so it vmaps across subgraphs.

Two implementations share this module:

* the **segment path** (:func:`hem_match` / :func:`contract`) — the seed's
  edge-array formulation: ``segment_max``/``segment_min`` proposal passes
  and a sort-based contraction. Exact (no degree cap); kept as the
  reference for the contraction invariants and as the PR 8 comparison
  mode (``partition(..., coarsen="segment")``).
* the **ELL kernel path** (:func:`hem_match_ell` / :func:`contract_ell` /
  :func:`coarsen_once` with ``ell_deg``) — row-tiled scans over the padded
  ``[N, DEG]`` ELL adjacency, dispatched through ``kernels/ops``
  (``hem_propose`` / ``contract_edges``) like the refinement kernels.
  Sort-free: proposals are per-row max scans, contraction merges each
  coarse row's (<= 2) member rows with a fixed-order dedup/accumulate and
  scatters straight into the relabeled CSR (a permutation — no float
  scatter-add races). Rows beyond the static ``DEG`` cap are truncated
  (the refinement kernels' overflow policy); coarsening is purely
  heuristic — partitions stay valid, cut/balance are always evaluated on
  the untruncated fine graph. Backends agree bitwise (kernels/ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .graph import Graph, default_ell_deg, edge_mask, ell_adjacency, vertex_mask
from ..kernels import ops as kops

_HASH_A = jnp.uint32(2654435761)
_HASH_B = jnp.uint32(40503)

# per-round salt stride: any odd constant; mixed into the edge jitter so
# round r+1 re-rolls every tie-break (see hem_match round fix below)
_ROUND_SALT = 101159


def _edge_jitter(rows: jax.Array, cols: jax.Array, salt) -> jax.Array:
    """Deterministic per-edge jitter in [0, 1), symmetric in (u, v).

    ``salt`` may be a Python int or a traced i32 scalar (the round loops
    pass ``base + r * _ROUND_SALT``); mixing happens in uint32 so the
    arithmetic wraps identically either way.
    """
    u = rows.astype(jnp.uint32)
    v = cols.astype(jnp.uint32)
    a, b = jnp.minimum(u, v), jnp.maximum(u, v)
    s = jnp.asarray(salt, jnp.int32).astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
    h = (a * _HASH_A) ^ (b * _HASH_B) ^ s
    h = (h ^ (h >> 16)) * jnp.uint32(0x45D9F3B)
    return (h & jnp.uint32(0xFFFFFF)).astype(jnp.float32) / float(1 << 24)


# ---------------------------------------------------------------------------
# segment path (seed formulation; exact, sort-based)
# ---------------------------------------------------------------------------

def hem_match(g: Graph, rounds: int = 3, salt: int = 0) -> jax.Array:
    """Heavy-edge matching. Returns cluster labels [N]: matched pairs share
    the smaller endpoint's id; unmatched vertices point to themselves."""
    N = g.N
    vmask = vertex_mask(g)
    emask = edge_mask(g)
    idx = jnp.arange(N, dtype=jnp.int32)
    labels = idx
    matched = ~vmask  # padding can never match

    def one_round(r, state):
        labels, matched = state
        free_edge = emask & ~matched[g.rows] & ~matched[g.cols] & (g.rows != g.cols)
        # r is mixed into the salt: with a round-invariant salt, a round
        # that matches nothing (cyclic proposals) reproduces the SAME
        # proposals forever and later rounds are dead weight.
        jit_ = _edge_jitter(g.rows, g.cols, salt * 7 + 13 + r * _ROUND_SALT) * 1e-3
        score = jnp.where(free_edge, g.ewgt * (1.0 + jit_) + jit_, -jnp.inf)
        row_best = jax.ops.segment_max(score, g.rows, num_segments=N)
        is_best = free_edge & (score >= row_best[g.rows]) & jnp.isfinite(score)
        # tie-break: smallest column among best-scoring edges
        prop_col = jax.ops.segment_min(
            jnp.where(is_best, g.cols, N), g.rows, num_segments=N
        )
        proposal = jnp.where((prop_col < N) & ~matched, prop_col, idx)
        # mutual handshake
        mutual = (proposal != idx) & (proposal[proposal] == idx)
        leader = jnp.minimum(idx, proposal)
        new_match = mutual & ~matched
        labels = jnp.where(new_match, leader, labels)
        matched = matched | new_match
        return labels, matched

    labels, matched = jax.lax.fori_loop(0, rounds, one_round, (labels, matched))
    return labels


@functools.partial(jax.jit, donate_argnums=())
def contract(g: Graph, labels: jax.Array) -> tuple[Graph, jax.Array]:
    """Contract clusters given by ``labels``. Returns (coarse graph with the
    SAME padded shapes, fine->coarse vertex map [N])."""
    N, M = g.N, g.M
    vmask = vertex_mask(g)
    emask = edge_mask(g)
    idx = jnp.arange(N, dtype=jnp.int32)

    is_leader = vmask & (labels == idx)
    rank = jnp.cumsum(is_leader.astype(jnp.int32)) - 1  # [N]
    n_coarse = jnp.sum(is_leader.astype(jnp.int32))
    # fine -> coarse id; padding parked at N-1 with zero weight
    newid = jnp.where(vmask, rank[labels], N - 1).astype(jnp.int32)

    vwgt_c = jax.ops.segment_sum(jnp.where(vmask, g.vwgt, 0.0), newid, num_segments=N)

    cu = newid[g.rows]
    cv = newid[g.cols]
    valid = emask & (cu != cv)
    # sort edges by (cu, cv) with invalid parked at cu = N (dropped on scatter)
    cu_s_key = jnp.where(valid, cu, N)
    order1 = jnp.argsort(jnp.where(valid, cv, N), stable=True)
    cu1, cv1, w1 = cu_s_key[order1], cv[order1], jnp.where(valid, g.ewgt, 0.0)[order1]
    order2 = jnp.argsort(cu1, stable=True)
    cu2, cv2, w2 = cu1[order2], cv1[order2], w1[order2]

    valid_s = cu2 < N
    head = valid_s & (
        (jnp.arange(M) == 0)
        | (cu2 != jnp.roll(cu2, 1))
        | (cv2 != jnp.roll(cv2, 1))
    )
    seg = jnp.cumsum(head.astype(jnp.int32)) - 1  # dedup segment id per slot
    agg_w = jax.ops.segment_sum(jnp.where(valid_s, w2, 0.0), jnp.maximum(seg, 0), num_segments=M)

    slot = jnp.where(head, seg, M)  # scatter position (M = drop)
    rows_c = jnp.full((M,), N - 1, jnp.int32).at[slot].set(cu2, mode="drop")
    cols_c = jnp.full((M,), N - 1, jnp.int32).at[slot].set(cv2, mode="drop")
    m_coarse = jnp.sum(head.astype(jnp.int32))
    in_range = jnp.arange(M) < m_coarse
    ewgt_c = jnp.where(in_range, agg_w, 0.0)
    rows_c = jnp.where(in_range, rows_c, N - 1)
    cols_c = jnp.where(in_range, cols_c, N - 1)

    # padded slots (>= m_coarse) anchor at row N-1 but the in_range gate
    # already zeroes their contribution, so counts is exact as-is. (An
    # earlier anchor correction subtracted the padded-slot count from row
    # N-1 a second time — corrupting that row's indptr whenever the coarse
    # graph filled the padded shape and N-1 was a REAL coarse vertex, and
    # leaving indptr[N] < m_coarse otherwise.)
    counts = jax.ops.segment_sum(in_range.astype(jnp.int32), rows_c, num_segments=N)
    indptr_c = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)]).astype(jnp.int32)

    gc = Graph(
        vwgt=vwgt_c,
        rows=rows_c,
        cols=cols_c,
        ewgt=ewgt_c,
        indptr=indptr_c,
        n=n_coarse.astype(jnp.int32),
        m=m_coarse.astype(jnp.int32),
    )
    return gc, newid


# ---------------------------------------------------------------------------
# ELL kernel path (row-tiled, sort-free; dispatched through kernels/ops)
# ---------------------------------------------------------------------------

def hem_match_ell(g: Graph, adj: jax.Array, adw: jax.Array,
                  rounds: int = 3, salt=0,
                  use_pallas: bool | None = None) -> jax.Array:
    """Heavy-edge matching over the ELL adjacency (kernel path).

    Same contract as :func:`hem_match` (labels [N], pairs share the
    smaller endpoint's id) but proposals come from the row-tiled
    ``kernels/ops.hem_propose`` scan; rows past the DEG cap see only
    their first DEG neighbours.
    """
    N = g.N
    vmask = vertex_mask(g)
    idx = jnp.arange(N, dtype=jnp.int32)
    u2d = jnp.broadcast_to(idx[:, None], adj.shape)
    labels = idx
    matched = (~vmask).astype(jnp.int32)  # padding can never match

    def one_round(r, state):
        labels, matched = state
        jit_ = _edge_jitter(u2d, adj, salt * 7 + 13 + r * _ROUND_SALT)
        prop = kops.hem_propose(adj, adw, jit_, matched, use_pallas)
        proposal = jnp.where((prop < N) & (matched == 0), prop, idx)
        mutual = (proposal != idx) & (proposal[proposal] == idx)
        leader = jnp.minimum(idx, proposal)
        new_match = mutual & (matched == 0)
        labels = jnp.where(new_match, leader, labels)
        matched = matched | new_match.astype(jnp.int32)
        return labels, matched

    labels, matched = jax.lax.fori_loop(0, rounds, one_round, (labels, matched))
    return labels


def contract_ell(g: Graph, labels: jax.Array, adj: jax.Array, adw: jax.Array,
                 use_pallas: bool | None = None) -> tuple[Graph, jax.Array]:
    """Contract matched pairs via the row-merge kernel (sort-free).

    Coarse row ``u`` holds the union of its (<= 2) fine members' ELL rows
    mapped through ``newid`` — deduped and weight-summed by
    ``kernels/ops.contract_edges`` in fixed slot order — then scattered
    straight into the relabeled CSR at ``indptr[u] + rank`` (a
    permutation, so the result is deterministic and ``rows`` stays
    sorted with an exact ``indptr`` prefix). Returns (coarse graph with
    the SAME padded shapes, fine->coarse map [N]).
    """
    N, M = g.N, g.M
    DEG = adj.shape[1]
    vmask = vertex_mask(g)
    idx = jnp.arange(N, dtype=jnp.int32)

    is_leader = vmask & (labels == idx)
    rank = jnp.cumsum(is_leader.astype(jnp.int32)) - 1
    n_coarse = jnp.sum(is_leader.astype(jnp.int32))
    newid = jnp.where(vmask, rank[labels], N - 1).astype(jnp.int32)

    # coarse row u's fine members: the leader and (if matched) its partner
    memA = (jnp.full((N,), N, jnp.int32)
            .at[jnp.where(is_leader, rank, N)].set(idx, mode="drop"))
    nonleader = vmask & (labels != idx)
    memB = (jnp.full((N,), N, jnp.int32)
            .at[jnp.where(nonleader, rank[jnp.clip(labels, 0, N - 1)], N)]
            .set(idx, mode="drop"))
    hasA = memA < N
    hasB = memB < N

    # exact pair sum (each coarse vertex has <= 2 members; pad rows -> 0)
    vwgt_c = (jnp.where(hasA, g.vwgt[jnp.clip(memA, 0, N - 1)], 0.0)
              + jnp.where(hasB, g.vwgt[jnp.clip(memB, 0, N - 1)], 0.0))

    def member_cands(mem, has):
        rowsel = jnp.clip(mem, 0, N - 1)
        a = adj[rowsel]                       # [N, DEG] member neighbour ids
        w = adw[rowsel]
        cn = newid[jnp.clip(a, 0, N - 1)]     # coarse-mapped neighbour
        ok = has[:, None] & (a < N) & (cn != idx[:, None])  # drop pad + intra
        return jnp.where(ok, cn, N), jnp.where(ok, w, 0.0)

    candA, candwA = member_cands(memA, hasA)
    candB, candwB = member_cands(memB, hasB)
    cand = jnp.concatenate([candA, candB], axis=1)    # [N, 2*DEG]
    candw = jnp.concatenate([candwA, candwB], axis=1)

    nbr, wsum, cnt = kops.contract_edges(cand, candw, use_pallas)

    counts = cnt.astype(jnp.int32)                    # [N]; pad rows 0
    indptr_c = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)]).astype(jnp.int32)
    m_coarse = indptr_c[-1]

    first = nbr < N
    rank_in_row = jnp.cumsum(first.astype(jnp.int32), axis=1) - 1
    dest = jnp.where(first, indptr_c[:N, None] + rank_in_row, M).reshape(-1)
    rowid = jnp.broadcast_to(idx[:, None], nbr.shape).reshape(-1)
    rows_c = jnp.full((M,), N - 1, jnp.int32).at[dest].set(rowid, mode="drop")
    cols_c = jnp.full((M,), N - 1, jnp.int32).at[dest].set(
        nbr.reshape(-1), mode="drop")
    ewgt_c = jnp.zeros((M,), adw.dtype).at[dest].set(
        wsum.reshape(-1), mode="drop")

    gc = Graph(
        vwgt=vwgt_c,
        rows=rows_c,
        cols=cols_c,
        ewgt=ewgt_c,
        indptr=indptr_c,
        n=n_coarse.astype(jnp.int32),
        m=m_coarse.astype(jnp.int32),
    )
    return gc, newid


def coarsen_once(g: Graph, salt=0, rounds: int = 3,
                 ell_deg: int | None = None,
                 use_pallas: bool | None = None) -> tuple[Graph, jax.Array]:
    """One HEM + contraction level.

    ``ell_deg=None`` runs the seed segment path; an int routes through the
    ELL kernels (the ELL adjacency is built ONCE and shared by matching
    and contraction — ``ell_adjacency`` needs no argsort thanks to the
    sorted-``rows`` invariant, which :func:`contract_ell` preserves, so
    the whole cascade is sort-free).
    """
    if ell_deg is None:
        labels = hem_match(g, rounds=rounds, salt=salt)
        return contract(g, labels)
    adj, adw, _ = ell_adjacency(g, ell_deg)
    labels = hem_match_ell(g, adj, adw, rounds=rounds, salt=salt,
                           use_pallas=use_pallas)
    return contract_ell(g, labels, adj, adw, use_pallas=use_pallas)


@functools.partial(jax.jit, static_argnames=("levels", "ell_deg", "rounds"))
def coarsen_cascade(g: Graph, levels: int, ell_deg: int | None = None,
                    rounds: int = 3):
    """Run the fused coarsening cascade alone and return per-level sizes
    ``(ns [levels], ms [levels])`` — the telemetry behind
    ``stats["coarsen"]`` and the large-instance benchmark tier. The scan
    carries ONLY the current graph (O(1) memory in ``levels``), so this
    path handles 10^6-vertex instances the full v-cycle's stacked
    uncoarsening arrays would not."""
    deg = default_ell_deg(g.N, g.M) if ell_deg is None else ell_deg
    salts = (jnp.arange(levels, dtype=jnp.int32) + 1) * 131 + 7

    def step(cur, sl):
        gc, _ = coarsen_once(cur, salt=sl, rounds=rounds, ell_deg=deg)
        return gc, (gc.n, gc.m)

    if levels == 0:
        z = jnp.zeros((0,), jnp.int32)
        return z, z
    _, (ns, ms) = jax.lax.scan(step, g, salts)
    return ns, ms
