"""Coarsening: heavy-edge matching (HEM) + contraction, fully vectorized.

Matching uses two-round handshaking: every unmatched vertex proposes to its
heaviest unmatched neighbour (deterministic jittered tie-breaks); mutual
proposals are contracted. This is the standard shared-memory parallel HEM
(cf. Mt-Metis / Mt-KaHyPar coarsening) re-expressed over static-shape CSR
arrays so it vmaps across subgraphs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .graph import Graph, edge_mask, vertex_mask

_HASH_A = jnp.uint32(2654435761)
_HASH_B = jnp.uint32(40503)


def _edge_jitter(rows: jax.Array, cols: jax.Array, salt: int) -> jax.Array:
    """Deterministic per-edge jitter in [0, 1), symmetric in (u, v)."""
    u = rows.astype(jnp.uint32)
    v = cols.astype(jnp.uint32)
    a, b = jnp.minimum(u, v), jnp.maximum(u, v)
    h = (a * _HASH_A) ^ (b * _HASH_B) ^ jnp.uint32((salt * 0x9E3779B9) & 0xFFFFFFFF)
    h = (h ^ (h >> 16)) * jnp.uint32(0x45D9F3B)
    return (h & jnp.uint32(0xFFFFFF)).astype(jnp.float32) / float(1 << 24)


def hem_match(g: Graph, rounds: int = 3, salt: int = 0) -> jax.Array:
    """Heavy-edge matching. Returns cluster labels [N]: matched pairs share
    the smaller endpoint's id; unmatched vertices point to themselves."""
    N = g.N
    vmask = vertex_mask(g)
    emask = edge_mask(g)
    idx = jnp.arange(N, dtype=jnp.int32)
    labels = idx
    matched = ~vmask  # padding can never match

    def one_round(r, state):
        labels, matched = state
        free_edge = emask & ~matched[g.rows] & ~matched[g.cols] & (g.rows != g.cols)
        jit_ = _edge_jitter(g.rows, g.cols, salt * 7 + 13) * 1e-3
        score = jnp.where(free_edge, g.ewgt * (1.0 + jit_) + jit_, -jnp.inf)
        row_best = jax.ops.segment_max(score, g.rows, num_segments=N)
        is_best = free_edge & (score >= row_best[g.rows]) & jnp.isfinite(score)
        # tie-break: smallest column among best-scoring edges
        prop_col = jax.ops.segment_min(
            jnp.where(is_best, g.cols, N), g.rows, num_segments=N
        )
        proposal = jnp.where((prop_col < N) & ~matched, prop_col, idx)
        # mutual handshake
        mutual = (proposal != idx) & (proposal[proposal] == idx)
        leader = jnp.minimum(idx, proposal)
        new_match = mutual & ~matched
        labels = jnp.where(new_match, leader, labels)
        matched = matched | new_match
        return labels, matched

    labels, matched = jax.lax.fori_loop(0, rounds, one_round, (labels, matched))
    return labels


@functools.partial(jax.jit, donate_argnums=())
def contract(g: Graph, labels: jax.Array) -> tuple[Graph, jax.Array]:
    """Contract clusters given by ``labels``. Returns (coarse graph with the
    SAME padded shapes, fine->coarse vertex map [N])."""
    N, M = g.N, g.M
    vmask = vertex_mask(g)
    emask = edge_mask(g)
    idx = jnp.arange(N, dtype=jnp.int32)

    is_leader = vmask & (labels == idx)
    rank = jnp.cumsum(is_leader.astype(jnp.int32)) - 1  # [N]
    n_coarse = jnp.sum(is_leader.astype(jnp.int32))
    # fine -> coarse id; padding parked at N-1 with zero weight
    newid = jnp.where(vmask, rank[labels], N - 1).astype(jnp.int32)

    vwgt_c = jax.ops.segment_sum(jnp.where(vmask, g.vwgt, 0.0), newid, num_segments=N)

    cu = newid[g.rows]
    cv = newid[g.cols]
    valid = emask & (cu != cv)
    # sort edges by (cu, cv) with invalid parked at cu = N (dropped on scatter)
    cu_s_key = jnp.where(valid, cu, N)
    order1 = jnp.argsort(jnp.where(valid, cv, N), stable=True)
    cu1, cv1, w1 = cu_s_key[order1], cv[order1], jnp.where(valid, g.ewgt, 0.0)[order1]
    order2 = jnp.argsort(cu1, stable=True)
    cu2, cv2, w2 = cu1[order2], cv1[order2], w1[order2]

    valid_s = cu2 < N
    head = valid_s & (
        (jnp.arange(M) == 0)
        | (cu2 != jnp.roll(cu2, 1))
        | (cv2 != jnp.roll(cv2, 1))
    )
    seg = jnp.cumsum(head.astype(jnp.int32)) - 1  # dedup segment id per slot
    agg_w = jax.ops.segment_sum(jnp.where(valid_s, w2, 0.0), jnp.maximum(seg, 0), num_segments=M)

    slot = jnp.where(head, seg, M)  # scatter position (M = drop)
    rows_c = jnp.full((M,), N - 1, jnp.int32).at[slot].set(cu2, mode="drop")
    cols_c = jnp.full((M,), N - 1, jnp.int32).at[slot].set(cv2, mode="drop")
    m_coarse = jnp.sum(head.astype(jnp.int32))
    in_range = jnp.arange(M) < m_coarse
    ewgt_c = jnp.where(in_range, agg_w, 0.0)
    rows_c = jnp.where(in_range, rows_c, N - 1)
    cols_c = jnp.where(in_range, cols_c, N - 1)

    # padded slots (>= m_coarse) anchor at row N-1 but the in_range gate
    # already zeroes their contribution, so counts is exact as-is. (An
    # earlier anchor correction subtracted the padded-slot count from row
    # N-1 a second time — corrupting that row's indptr whenever the coarse
    # graph filled the padded shape and N-1 was a REAL coarse vertex, and
    # leaving indptr[N] < m_coarse otherwise.)
    counts = jax.ops.segment_sum(in_range.astype(jnp.int32), rows_c, num_segments=N)
    indptr_c = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)]).astype(jnp.int32)

    gc = Graph(
        vwgt=vwgt_c,
        rows=rows_c,
        cols=cols_c,
        ewgt=ewgt_c,
        indptr=indptr_c,
        n=n_coarse.astype(jnp.int32),
        m=m_coarse.astype(jnp.int32),
    )
    return gc, newid


def coarsen_once(g: Graph, salt: int = 0, rounds: int = 3) -> tuple[Graph, jax.Array]:
    """One HEM + contraction level."""
    labels = hem_match(g, rounds=rounds, salt=salt)
    return contract(g, labels)
