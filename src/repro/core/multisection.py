"""Hierarchical multisection (the paper's §4) with scheduling strategies.

The communication graph is partitioned along the hierarchy
``H = a_1 : ... : a_l`` (top-down: first a_l, then a_{l-1}, ...), with the
adaptive imbalance of Lemma 5.1 applied at every sub-partition, so the final
k-way partition is eps-balanced and the identity mapping solves the mapping
phase.

Scheduling strategies (§4.2-4.5), adapted from C++ threads to JAX/XLA:

* ``naive``   — partition one subgraph at a time (all compute on one task).
* ``layer``   — all subgraphs of one hierarchy level padded to a common
                shape and partitioned by ONE vmapped program (the level
                barrier is the program boundary). Paper: Algorithm 1.
* ``bucket``  — the NON-BLOCKING LAYER analogue: subgraphs of a level are
                grouped into power-of-two size buckets; each bucket is its
                own vmapped program, so small subgraphs do not pay the
                padding (idle-lane) cost of the largest one.
* ``queue``   — the PRIORITY QUEUE analogue: a host-side master thread pops
                the largest pending subgraph and dispatches its partition
                call to a worker pool (XLA dispatch is asynchronous).
                Paper: Algorithm 2.

All strategies use salts derived from the subgraph's position in the
hierarchy (not traversal order), so results are reproducible per strategy.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph
from .hierarchy import Hierarchy, adaptive_epsilon
from .partition import num_levels, partition


# ---------------------------------------------------------------------------
# host-side subgraph extraction
# ---------------------------------------------------------------------------

def _next_pow2(x: int) -> int:
    return 1 << max(int(np.ceil(np.log2(max(x, 1)))), 0)


@dataclasses.dataclass
class _HostGraph:
    """Numpy mirror of a (sub)graph + bookkeeping for the recursion."""

    vwgt: np.ndarray   # [n]
    rows: np.ndarray   # [m] directed
    cols: np.ndarray   # [m]
    ewgt: np.ndarray   # [m]
    orig_ids: np.ndarray  # [n] vertex ids in the ORIGINAL graph
    depth: int         # hierarchy depth (l at the root, 0 at leaves)
    pe_base: int       # PE id offset accumulated along the recursion
    uid: int           # stable id along the hierarchy path (for salts)

    @property
    def n(self) -> int:
        return self.vwgt.shape[0]

    @property
    def m(self) -> int:
        return self.rows.shape[0]

    def to_device(self, N: int, M: int) -> Graph:
        rows = np.full(M, N - 1, np.int32)
        cols = np.full(M, N - 1, np.int32)
        ewgt = np.zeros(M, np.float32)
        rows[: self.m] = self.rows
        cols[: self.m] = self.cols
        ewgt[: self.m] = self.ewgt
        vwgt = np.zeros(N, np.float32)
        vwgt[: self.n] = self.vwgt
        counts = np.bincount(self.rows, minlength=N)
        indptr = np.zeros(N + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        return Graph(
            vwgt=jnp.asarray(vwgt),
            rows=jnp.asarray(rows),
            cols=jnp.asarray(cols),
            ewgt=jnp.asarray(ewgt),
            indptr=jnp.asarray(np.minimum(indptr, self.m), jnp.int32),
            n=jnp.asarray(self.n, jnp.int32),
            m=jnp.asarray(self.m, jnp.int32),
        )


def host_graph_from(g: Graph) -> _HostGraph:
    n = int(g.n)
    m = int(g.m)
    return _HostGraph(
        vwgt=np.asarray(g.vwgt)[:n].astype(np.float64),
        rows=np.asarray(g.rows)[:m].astype(np.int64),
        cols=np.asarray(g.cols)[:m].astype(np.int64),
        ewgt=np.asarray(g.ewgt)[:m].astype(np.float64),
        orig_ids=np.arange(n, dtype=np.int64),
        depth=0,
        pe_base=0,
        uid=0,
    )


def _split(hg: _HostGraph, part: np.ndarray, k: int, child_depth: int,
           stride: int, arity: int) -> list[_HostGraph]:
    """Extract the k induced block subgraphs of ``hg`` under ``part``."""
    part = part[: hg.n]
    relabel = np.zeros(hg.n, np.int64)
    children = []
    for b in range(k):
        sel = np.nonzero(part == b)[0]
        relabel[sel] = np.arange(sel.shape[0])
        emask = (part[hg.rows] == b) & (part[hg.cols] == b)
        children.append(
            _HostGraph(
                vwgt=hg.vwgt[sel],
                rows=relabel[hg.rows[emask]],
                cols=relabel[hg.cols[emask]],
                ewgt=hg.ewgt[emask],
                orig_ids=hg.orig_ids[sel],
                depth=child_depth,
                pe_base=hg.pe_base + b * stride,
                uid=hg.uid * arity + b + 1,
            )
        )
    return children


# ---------------------------------------------------------------------------
# the multisection driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MultisectionResult:
    pe_of: np.ndarray            # [n] PE assignment (the mapping Pi)
    stats: dict                   # timing / scheduling telemetry


PartitionFn = Callable[..., jax.Array]


def _eps_for(hg: _HostGraph, h: Hierarchy, eps: float, total_weight: float,
             adaptive: bool) -> float:
    if not adaptive:
        return eps
    d = hg.depth
    k_sub = int(np.prod(h.a[:d])) if d > 0 else 1
    return adaptive_epsilon(eps, total_weight, float(hg.vwgt.sum()), h.k, k_sub, d)


def _partition_one(hg: _HostGraph, k: int, eps_val: float, preset: str,
                   salt: int, pad_n: int | None = None, pad_m: int | None = None) -> np.ndarray:
    N = pad_n or _next_pow2(hg.n)
    M = pad_m or _next_pow2(max(hg.m, 1))
    g = hg.to_device(N, M)
    lv = num_levels(N, k)
    part = partition(g, k, jnp.float32(eps_val), lv, preset, salt)
    return np.asarray(part)[: hg.n]


def hierarchical_multisection(
    g: Graph,
    h: Hierarchy,
    eps: float = 0.03,
    preset: str = "eco",
    strategy: str = "bucket",
    seed: int = 0,
    adaptive: bool = True,
) -> MultisectionResult:
    """Partition ``g`` along ``h`` and return the (identity) mapping."""
    root = host_graph_from(g)
    root.depth = h.l
    total_weight = float(root.vwgt.sum())
    strides = (1,) + h.strides  # strides[d] = PEs under one depth-d block
    pe_of = np.zeros(root.n, np.int64)
    stats = {"partition_calls": 0, "levels": [], "strategy": strategy,
             "padded_vertex_work": 0, "real_vertex_work": 0}

    def record(batchN, realn):
        stats["padded_vertex_work"] += int(batchN)
        stats["real_vertex_work"] += int(realn)

    current = [root]
    t0 = time.time()
    while current:
        nxt: list[_HostGraph] = []
        leaves = [hg for hg in current if hg.depth == 0]
        for hg in leaves:
            pe_of[hg.orig_ids] = hg.pe_base
        work = [hg for hg in current if hg.depth > 0]
        if not work:
            break
        lvl_t0 = time.time()
        if strategy == "naive":
            produced = _run_naive(work, h, eps, preset, seed, total_weight, adaptive, record)
        elif strategy == "layer":
            produced = _run_layer(work, h, eps, preset, seed, total_weight, adaptive, record, bucketed=False)
        elif strategy == "bucket":
            produced = _run_layer(work, h, eps, preset, seed, total_weight, adaptive, record, bucketed=True)
        elif strategy == "queue":
            produced = _run_queue(work, h, eps, preset, seed, total_weight, adaptive, record)
        else:
            raise ValueError(f"unknown strategy {strategy!r}")
        stats["partition_calls"] += len(work)
        stats["levels"].append({"graphs": len(work), "seconds": time.time() - lvl_t0})
        nxt.extend(produced)
        current = nxt
    stats["seconds"] = time.time() - t0
    return MultisectionResult(pe_of=pe_of, stats=stats)


def _children_of(hg: _HostGraph, part: np.ndarray, h: Hierarchy) -> list[_HostGraph]:
    d = hg.depth
    arity = h.a[d - 1]
    child_stride = int(np.prod(h.a[: d - 1])) if d > 1 else 1
    return _split(hg, part, arity, d - 1, child_stride, arity)


def _run_naive(work, h, eps, preset, seed, total_weight, adaptive, record):
    out = []
    for hg in work:
        arity = h.a[hg.depth - 1]
        e = _eps_for(hg, h, eps, total_weight, adaptive)
        part = _partition_one(hg, arity, e, preset, salt=seed * 100003 + hg.uid)
        record(_next_pow2(hg.n), hg.n)
        out.extend(_children_of(hg, part, h))
    return out


def _run_layer(work, h, eps, preset, seed, total_weight, adaptive, record, bucketed: bool):
    """One vmapped partition program per (bucket x arity) group."""
    groups: dict[tuple[int, int, int], list[_HostGraph]] = {}
    for hg in work:
        if bucketed:
            key_n = _next_pow2(hg.n)
            key_m = _next_pow2(max(hg.m, 1))
        else:
            key_n = key_m = 0  # one group per arity; padded to layer max below
        arity = h.a[hg.depth - 1]
        groups.setdefault((key_n, key_m, arity), []).append(hg)

    out = []
    for (kn, km, arity), members in groups.items():
        N = kn or _next_pow2(max(m.n for m in members))
        M = km or _next_pow2(max(max(m.m, 1) for m in members))
        gs = [m.to_device(N, M) for m in members]
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), *gs)
        eps_arr = jnp.asarray(
            [_eps_for(m, h, eps, total_weight, adaptive) for m in members], jnp.float32
        )
        salts = jnp.asarray([seed * 100003 + m.uid for m in members], jnp.int32)
        lv = num_levels(N, arity)
        parts = jax.vmap(lambda gg, ee, ss: partition(gg, arity, ee, lv, preset, ss))(
            batch, eps_arr, salts
        )
        parts = np.asarray(parts)
        for m_i, hg in enumerate(members):
            record(N, hg.n)
            out.extend(_children_of(hg, parts[m_i][: hg.n], h))
    return out


def _run_queue(work, h, eps, preset, seed, total_weight, adaptive, record, workers: int = 4):
    """PRIORITY QUEUE (Algorithm 2): master pops the largest subgraph,
    dispatches to a worker; children re-enter the queue. Because XLA
    executes dispatched programs asynchronously, host worker threads play
    the role of the paper's thread groups."""
    heap: list[tuple[int, int, _HostGraph]] = []
    lock = threading.Lock()
    out: list[_HostGraph] = []
    pending = [0]  # number of in-flight + queued tasks
    done = threading.Event()

    def push(hg: _HostGraph):
        with lock:
            heapq.heappush(heap, (-hg.n, hg.uid, hg))
            pending[0] += 1

    for hg in work:
        push(hg)

    def worker():
        while True:
            with lock:
                if pending[0] == 0:
                    done.set()
                    return
                if not heap:
                    task = None
                else:
                    task = heapq.heappop(heap)[2]
            if task is None:
                if done.is_set():
                    return
                time.sleep(0.001)
                continue
            arity = h.a[task.depth - 1]
            e = _eps_for(task, h, eps, total_weight, adaptive)
            part = _partition_one(task, arity, e, preset, salt=seed * 100003 + task.uid)
            record(_next_pow2(task.n), task.n)
            children = _children_of(task, part, h)
            with lock:
                pending[0] -= 1
                for c in children:
                    if c.depth > 0:
                        heapq.heappush(heap, (-c.n, c.uid, c))
                        pending[0] += 1
                    else:
                        out.append(c)
                if pending[0] == 0:
                    done.set()
                    return

    threads = [threading.Thread(target=worker) for _ in range(workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return out


STRATEGIES = ("naive", "layer", "bucket", "queue")
