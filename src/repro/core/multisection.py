"""Hierarchical multisection (the paper's §4) with scheduling strategies.

The communication graph is partitioned along the hierarchy
``H = a_1 : ... : a_l`` (top-down: first a_l, then a_{l-1}, ...), with the
adaptive imbalance of Lemma 5.1 applied at every sub-partition, so the final
k-way partition is eps-balanced and the identity mapping solves the mapping
phase.

Scheduling strategies (§4.2-4.5), adapted from C++ threads to JAX/XLA:

* ``naive``   — partition one subgraph at a time (all compute on one task).
* ``layer``   — all subgraphs of one hierarchy level padded to a common
                shape and partitioned by ONE vmapped program (the level
                barrier is the program boundary). Paper: Algorithm 1.
* ``bucket``  — the NON-BLOCKING LAYER analogue: subgraphs of a level are
                grouped into power-of-two size buckets; each bucket is its
                own vmapped program, so small subgraphs do not pay the
                padding (idle-lane) cost of the largest one.
* ``queue``   — the PRIORITY QUEUE analogue: worker threads pop the largest
                pending subgraph from a condition-variable-guarded heap and
                dispatch its partition call (XLA dispatch is asynchronous,
                so one worker's host-side subgraph extraction overlaps
                another's device compute). Paper: Algorithm 2.

Planner / executor split
------------------------
The LAYER/BUCKET strategies are expressed as a reusable two-phase planner
so that an external scheduler can interleave work from MANY in-flight
hierarchies (serve/mapper.MappingService):

* :func:`plan_level` turns one hierarchy level's pending subgraphs into
  :class:`PlanGroup`s — pure bookkeeping, no device work. Each group
  carries everything a dispatch needs (members, padded shapes, arity,
  preset/backend/ELL-degree, per-member eps and salts).
* :func:`execute_group_batch` runs one stacked vmapped dispatch for one or
  MORE groups sharing :attr:`PlanGroup.exec_key` — the cross-request
  coalescing primitive. vmap lanes are independent, so a member's result
  is bit-identical whatever batch it rides in (tested).
* :class:`LevelPlanner` is the level-stepped state machine driving one
  hierarchy: ``plan() -> execute -> advance`` until done. The in-process
  bucket/layer path of :func:`hierarchical_multisection` runs on the SAME
  planner, so the direct path and the mapping service share every
  planning decision — the precondition for bit-identical results.

Compile-cache policy
--------------------
Single-subgraph calls go straight to the jitted ``partition`` (its jit
cache is keyed by the static ``(k, levels, preset, backend, ell_deg)``
plus the padded ``(N, M)`` shapes); bucket calls go through
:func:`_batched_partition`, a process-wide memo of jitted vmapped wrappers
keyed by ``(k, levels, preset, backend, ell_deg)`` — the seed rebuilt a
``jax.vmap(lambda ...)`` per bucket per level, paying a full retrace per
call. Both paths are shared across hierarchy levels, strategies and
`hierarchical_multisection` calls. :func:`_note_program` tracks every
distinct XLA program key ``(N, M, batch, k, levels, preset, backend,
ell_deg)``:
first sighting in the process = compile (miss), later sightings = reuse
(hit); per-run counts land in ``stats["compile_cache"]``.

Device-transfer policy: each bucket's members are stacked host-side into
one ``[B, ...]`` numpy buffer per Graph field and shipped with a single
transfer per field (the seed did one transfer per field PER MEMBER).

All strategies use salts derived from the subgraph's position in the
hierarchy (not traversal order), so results are reproducible per strategy
— and identical ACROSS strategies up to padding effects (`queue` and
`naive` pad identically, so they produce bit-equal mappings).
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph, assemble_padded, default_ell_deg, padded_csr_indptr
from .hierarchy import Hierarchy, adaptive_epsilon
from .partition import num_levels, partition
from .refine import resolve_backend


# ---------------------------------------------------------------------------
# host-side subgraph extraction
# ---------------------------------------------------------------------------

def _next_pow2(x: int) -> int:
    return 1 << max(int(np.ceil(np.log2(max(x, 1)))), 0)


@dataclasses.dataclass
class _HostGraph:
    """Numpy mirror of a (sub)graph + bookkeeping for the recursion."""

    vwgt: np.ndarray   # [n]
    rows: np.ndarray   # [m] directed
    cols: np.ndarray   # [m]
    ewgt: np.ndarray   # [m]
    orig_ids: np.ndarray  # [n] vertex ids in the ORIGINAL graph
    depth: int         # hierarchy depth (l at the root, 0 at leaves)
    pe_base: int       # PE id offset accumulated along the recursion
    uid: int           # stable id along the hierarchy path (for salts)

    @property
    def n(self) -> int:
        return self.vwgt.shape[0]

    @property
    def m(self) -> int:
        return self.rows.shape[0]

    def to_device(self, N: int, M: int) -> Graph:
        """Padded device Graph via the shared CSR builder (exact indptr)."""
        return assemble_padded(self.vwgt, self.rows, self.cols, self.ewgt,
                               self.n, N, M)


def _stack_to_device(members: list[_HostGraph], N: int, M: int) -> Graph:
    """Batched [B, ...] Graph for a bucket — ONE host->device transfer per
    field instead of one per member per field."""
    B = len(members)
    vwgt = np.zeros((B, N), np.float32)
    rows = np.full((B, M), N - 1, np.int32)
    cols = np.full((B, M), N - 1, np.int32)
    ewgt = np.zeros((B, M), np.float32)
    indptr = np.zeros((B, N + 1), np.int32)
    ns = np.zeros((B,), np.int32)
    ms = np.zeros((B,), np.int32)
    for i, hg in enumerate(members):
        m = hg.m
        vwgt[i, : hg.n] = hg.vwgt
        rows[i, :m] = hg.rows
        cols[i, :m] = hg.cols
        ewgt[i, :m] = hg.ewgt
        indptr[i] = padded_csr_indptr(rows[i], m, N)
        ns[i] = hg.n
        ms[i] = m
    return Graph(
        vwgt=jnp.asarray(vwgt),
        rows=jnp.asarray(rows),
        cols=jnp.asarray(cols),
        ewgt=jnp.asarray(ewgt),
        indptr=jnp.asarray(indptr),
        n=jnp.asarray(ns),
        m=jnp.asarray(ms),
    )


def host_graph_from(g: Graph) -> _HostGraph:
    n = int(g.n)
    m = int(g.m)
    return _HostGraph(
        vwgt=np.asarray(g.vwgt)[:n].astype(np.float64),
        rows=np.asarray(g.rows)[:m].astype(np.int64),
        cols=np.asarray(g.cols)[:m].astype(np.int64),
        ewgt=np.asarray(g.ewgt)[:m].astype(np.float64),
        orig_ids=np.arange(n, dtype=np.int64),
        depth=0,
        pe_base=0,
        uid=0,
    )


def _split(hg: _HostGraph, part: np.ndarray, k: int, child_depth: int,
           stride: int, arity: int) -> list[_HostGraph]:
    """Extract the k induced block subgraphs of ``hg`` under ``part``."""
    part = part[: hg.n]
    relabel = np.zeros(hg.n, np.int64)
    children = []
    for b in range(k):
        sel = np.nonzero(part == b)[0]
        relabel[sel] = np.arange(sel.shape[0])
        emask = (part[hg.rows] == b) & (part[hg.cols] == b)
        children.append(
            _HostGraph(
                vwgt=hg.vwgt[sel],
                rows=relabel[hg.rows[emask]],
                cols=relabel[hg.cols[emask]],
                ewgt=hg.ewgt[emask],
                orig_ids=hg.orig_ids[sel],
                depth=child_depth,
                pe_base=hg.pe_base + b * stride,
                uid=hg.uid * arity + b + 1,
            )
        )
    return children


# ---------------------------------------------------------------------------
# the compiled-callable cache
# ---------------------------------------------------------------------------

_VMAP_CACHE: dict[tuple, Callable] = {}  # (k, levels, preset, backend, deg) -> jitted
_SEEN_SHAPES: set[tuple] = set()         # program keys ever compiled
_EXEC_LOCK = threading.Lock()


def _ell_deg_for(members, backend: str) -> int | None:
    """Static ELL degree cap for a dispatch, from the REAL mean directed
    degree pooled over the member subgraphs: ``ceil(sum m / sum n)``
    (pow2-padded shapes skew the in-jit default by up to 2x — see
    core/refine.py). Taking the MAX of per-member ceil-means, as this used
    to, over-padded mixed buckets and fragmented the jit cache per outlier
    member. None when the xla backend doesn't need it (avoids fragmenting
    the jit cache key)."""
    if backend != "ell":
        return None
    tot_m = sum(m.m for m in members)
    tot_n = max(sum(m.n for m in members), 1)
    mean = (tot_m + tot_n - 1) // tot_n
    return default_ell_deg(1, mean)  # N=1, M=mean -> cap from the real mean


def _batched_partition(k: int, levels: int, preset: str, backend: str,
                       ell_deg: int | None) -> Callable:
    """Memoized jitted vmapped partition callable.

    The seed rebuilt ``jax.vmap(lambda ...)`` per bucket per level — a full
    retrace per call. The memoized jitted wrapper hits jit's C++ fast path
    on every repeat call with the same shapes (an AOT ``.lower().compile()``
    executable was measured SLOWER here: its Python ``Compiled.__call__``
    costs more than jit dispatch).
    """
    key = (k, levels, preset, backend, ell_deg)
    with _EXEC_LOCK:
        fn = _VMAP_CACHE.get(key)
        if fn is None:
            fn = jax.jit(lambda gs, ee, ss: jax.vmap(
                lambda g1, e1, s1: partition(g1, k, e1, levels, preset, s1,
                                             backend, ell_deg)
            )(gs, ee, ss))
            _VMAP_CACHE[key] = fn
    return fn


def _note_program(N: int, M: int, batch: int, k: int, levels: int, preset: str,
                  backend: str, ell_deg: int | None, cache_stats: dict) -> None:
    """Track XLA program reuse: the first sighting of a program key in the
    process is a compile (miss), every later one a cache hit."""
    key = (N, M, batch, k, levels, preset, backend, ell_deg)
    with _EXEC_LOCK:
        hit = key in _SEEN_SHAPES
        _SEEN_SHAPES.add(key)
        # increment inside the lock: queue workers call this concurrently
        cache_stats["hits" if hit else "misses"] += 1


def compile_cache_size() -> int:
    with _EXEC_LOCK:
        return len(_SEEN_SHAPES)


def clear_compile_cache() -> None:
    """Drop the memoized callables AND the program-sighting telemetry.

    Call alongside ``jax.clear_caches()`` — that drops the compiled
    executables inside the memoized jit wrappers, so keeping
    ``_SEEN_SHAPES`` would report 'hits' for programs XLA must recompile.
    """
    with _EXEC_LOCK:
        _VMAP_CACHE.clear()
        _SEEN_SHAPES.clear()


# ---------------------------------------------------------------------------
# the level planner (shared by the in-process strategies and serve/mapper)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PlanGroup:
    """One bucket dispatch planned from a single hierarchy's current level.

    Pure host-side bookkeeping: no device arrays, no compiled callables.
    ``eps``/``salts`` are per-member (position-derived, so independent of
    which batch the member eventually rides in).
    """

    members: list[_HostGraph]
    N: int                # padded vertex shape of the dispatch
    M: int                # padded edge shape
    arity: int            # k of each member's sub-partition
    levels: int           # static coarsening depth for (N, arity)
    preset: str
    backend: str
    deg: int | None       # static ELL degree cap (None for xla)
    eps: list[float]
    salts: list[int]

    @property
    def exec_key(self) -> tuple:
        """Groups with equal keys run the same compiled executable and may
        be stacked into ONE dispatch (the cross-request coalescing key)."""
        return (self.N, self.M, self.arity, self.levels, self.preset,
                self.backend, self.deg)


def plan_level(work: list[_HostGraph], h: Hierarchy, eps: float, preset: str,
               seed: int, total_weight: float, adaptive: bool, backend: str,
               bucketed: bool = True) -> list[PlanGroup]:
    """Group one level's pending subgraphs into dispatch units.

    ``bucketed=True`` is the BUCKET strategy (power-of-two shape buckets);
    ``False`` is LAYER (one group per arity, padded to the level max).
    """
    groups: dict[tuple[int, int, int], list[_HostGraph]] = {}
    for hg in work:
        if bucketed:
            key_n = _next_pow2(hg.n)
            key_m = _next_pow2(max(hg.m, 1))
        else:
            key_n = key_m = 0  # one group per arity; padded to layer max below
        arity = h.a[hg.depth - 1]
        groups.setdefault((key_n, key_m, arity), []).append(hg)

    out = []
    for (kn, km, arity), members in groups.items():
        N = kn or _next_pow2(max(m.n for m in members))
        M = km or _next_pow2(max(max(m.m, 1) for m in members))
        out.append(PlanGroup(
            members=members, N=N, M=M, arity=arity,
            levels=num_levels(N, arity), preset=preset, backend=backend,
            deg=_ell_deg_for(members, backend),
            eps=[_eps_for(m, h, eps, total_weight, adaptive) for m in members],
            salts=[seed * 100003 + m.uid for m in members],
        ))
    return out


def dispatch_group_batch(groups: list[PlanGroup], cache_stats: dict,
                         pad_batch_pow2: bool = False) -> tuple:
    """Stack and dispatch ONE vmapped call for PlanGroups sharing
    ``exec_key``; returns an opaque handle for :func:`fetch_group_batch`.

    XLA dispatch is asynchronous, so a scheduler can dispatch every merged
    set of a level before fetching any — host-side stacking of the next
    set overlaps device compute of the previous one (serve/mapper).

    ``pad_batch_pow2`` replicates the last member up to the next power of
    two (spare lanes dropped): the service uses it to bound the number of
    distinct batch widths XLA must compile for, at the cost of idle-lane
    compute on ragged batches.
    """
    key = groups[0].exec_key
    for gr in groups[1:]:
        if gr.exec_key != key:
            raise ValueError(f"mismatched exec keys: {gr.exec_key} != {key}")
    g0 = groups[0]
    members = [m for gr in groups for m in gr.members]
    eps = [e for gr in groups for e in gr.eps]
    salts = [s for gr in groups for s in gr.salts]
    B = len(members)
    Bp = _next_pow2(B) if pad_batch_pow2 else B
    if Bp > B:
        members = members + [members[-1]] * (Bp - B)
        eps = eps + [eps[-1]] * (Bp - B)
        salts = salts + [salts[-1]] * (Bp - B)
    _note_program(g0.N, g0.M, Bp, g0.arity, g0.levels, g0.preset, g0.backend,
                  g0.deg, cache_stats)
    fn = _batched_partition(g0.arity, g0.levels, g0.preset, g0.backend, g0.deg)
    batch = _stack_to_device(members, g0.N, g0.M)
    parts = fn(batch, jnp.asarray(eps, jnp.float32),
               jnp.asarray(salts, jnp.int32))
    return parts, groups


def fetch_group_batch(handle: tuple) -> list[np.ndarray]:
    """Block on a dispatched batch; one ``[B_i, N]`` array per group."""
    parts, groups = handle
    parts = np.asarray(parts)
    out = []
    ofs = 0
    for gr in groups:
        out.append(parts[ofs: ofs + len(gr.members)])
        ofs += len(gr.members)
    return out


def execute_group_batch(groups: list[PlanGroup], cache_stats: dict,
                        pad_batch_pow2: bool = False) -> list[np.ndarray]:
    """Dispatch + fetch in one call (the in-process strategies' path).

    Returns one ``[B_i, N]`` partition array per input group, in order.
    Because vmap lanes are independent, each member's partition is
    bit-identical to what a solo dispatch would produce — so coalescing
    groups from different requests cannot change any request's result.
    """
    return fetch_group_batch(
        dispatch_group_batch(groups, cache_stats, pad_batch_pow2))


class LevelPlanner:
    """Level-stepped multisection state machine for ONE hierarchy.

    Alternates ``plan()`` (PlanGroups for the current level; pure host
    work) with ``advance(results)`` (feed partition results, split
    children, step to the next level) until ``plan()`` returns ``[]``.
    The executor is external, so a scheduler holding several planners can
    merge their same-``exec_key`` groups into shared dispatches
    (serve/mapper.MappingService) — while the in-process bucket/layer path
    executes each group alone, yielding identical per-member programs.
    """

    def __init__(self, g: Graph, h: Hierarchy, eps: float = 0.03,
                 preset: str = "eco", seed: int = 0, adaptive: bool = True,
                 backend: str = "auto", bucketed: bool = True,
                 checkpoint: Callable[[], None] | None = None):
        self.h = h
        self.checkpoint = checkpoint
        self.eps = eps
        self.preset = preset
        self.seed = seed
        self.adaptive = adaptive
        self.backend = resolve_backend(backend)
        self.bucketed = bucketed
        root = host_graph_from(g)
        root.depth = h.l
        self.total_weight = float(root.vwgt.sum())
        self.pe_of = np.zeros(root.n, np.int64)
        self.stats = {"partition_calls": 0, "levels": [],
                      "strategy": "bucket" if bucketed else "layer",
                      "padded_vertex_work": 0, "real_vertex_work": 0,
                      "backend": self.backend,
                      "compile_cache": {"hits": 0, "misses": 0}}
        self.cache_stats = self.stats["compile_cache"]
        self._t0 = time.time()
        self._level_t0: float | None = None
        self._current: list[_HostGraph] = [root]
        self._work: list[_HostGraph] = []
        self._groups: list[PlanGroup] | None = None
        self._done = False

    @property
    def done(self) -> bool:
        return self._done

    def plan(self) -> list[PlanGroup]:
        """PlanGroups for the current level; ``[]`` once fully partitioned.
        Idempotent until ``advance`` consumes the results."""
        if self._done:
            return []
        if self._groups is None:
            # cooperative cancellation checkpoint: a deadline/shutdown hook
            # may abort here, BETWEEN levels, instead of after the full
            # pipeline (serve/mapper deadlines, close(wait=False)).
            if self.checkpoint is not None:
                self.checkpoint()
            for hg in self._current:
                if hg.depth == 0:
                    self.pe_of[hg.orig_ids] = hg.pe_base
            self._work = [hg for hg in self._current if hg.depth > 0]
            if not self._work:
                self._finish()
                return []
            self._level_t0 = time.time()
            self._groups = plan_level(
                self._work, self.h, self.eps, self.preset, self.seed,
                self.total_weight, self.adaptive, self.backend, self.bucketed)
        return self._groups

    def advance(self, results: list[np.ndarray]) -> None:
        """Feed one ``[B_i, N]`` partition array per group from ``plan()``."""
        groups = self.plan()
        if len(results) != len(groups):
            raise ValueError(f"expected {len(groups)} results, got {len(results)}")
        nxt: list[_HostGraph] = []
        for gr, parts in zip(groups, results):
            for i, hg in enumerate(gr.members):
                self._record(gr.N, hg.n)
                nxt.extend(_children_of(hg, parts[i][: hg.n], self.h))
        self.stats["levels"].append(
            {"graphs": len(self._work), "seconds": time.time() - self._level_t0})
        self._current = nxt
        self._groups = None

    def _record(self, batchN: int, realn: int) -> None:
        self.stats["partition_calls"] += 1
        self.stats["padded_vertex_work"] += int(batchN)
        self.stats["real_vertex_work"] += int(realn)

    def _finish(self) -> None:
        if not self._done:
            self._done = True
            self.stats["seconds"] = time.time() - self._t0

    def result(self) -> "MultisectionResult":
        if not self._done:
            raise RuntimeError("planner has pending levels")
        return MultisectionResult(pe_of=self.pe_of, stats=self.stats)


# ---------------------------------------------------------------------------
# the multisection driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MultisectionResult:
    pe_of: np.ndarray            # [n] PE assignment (the mapping Pi)
    stats: dict                   # timing / scheduling telemetry


PartitionFn = Callable[..., jax.Array]


def _eps_for(hg: _HostGraph, h: Hierarchy, eps: float, total_weight: float,
             adaptive: bool) -> float:
    if not adaptive:
        return eps
    d = hg.depth
    k_sub = int(np.prod(h.a[:d])) if d > 0 else 1
    return adaptive_epsilon(eps, total_weight, float(hg.vwgt.sum()), h.k, k_sub, d)


def _partition_one(hg: _HostGraph, k: int, eps_val: float, preset: str,
                   salt: int, backend: str, cache_stats: dict,
                   pad_n: int | None = None, pad_m: int | None = None) -> np.ndarray:
    N = pad_n or _next_pow2(hg.n)
    M = pad_m or _next_pow2(max(hg.m, 1))
    lv = num_levels(N, k)
    deg = _ell_deg_for([hg], backend)
    _note_program(N, M, 0, k, lv, preset, backend, deg, cache_stats)
    g = hg.to_device(N, M)
    part = partition(g, k, jnp.float32(eps_val), lv, preset, jnp.int32(salt),
                     backend, deg)
    return np.asarray(part)[: hg.n]


def hierarchical_multisection(
    g: Graph,
    h: Hierarchy,
    eps: float = 0.03,
    preset: str = "eco",
    strategy: str = "bucket",
    seed: int = 0,
    adaptive: bool = True,
    backend: str = "auto",
    checkpoint: Callable[[], None] | None = None,
) -> MultisectionResult:
    """Partition ``g`` along ``h`` and return the (identity) mapping.

    ``checkpoint`` is an optional cooperative-cancellation hook invoked
    between levels (and before each naive/queue task); raising inside it
    aborts the multisection — the mechanism behind service deadlines.
    """
    backend = resolve_backend(backend)
    if strategy in ("layer", "bucket"):
        # the planner path: identical planning to serve/mapper, each group
        # executed alone (no cross-request members to coalesce here).
        planner = LevelPlanner(g, h, eps=eps, preset=preset, seed=seed,
                               adaptive=adaptive, backend=backend,
                               bucketed=(strategy == "bucket"),
                               checkpoint=checkpoint)
        while True:
            groups = planner.plan()
            if not groups:
                break
            planner.advance([execute_group_batch([gr], planner.cache_stats)[0]
                             for gr in groups])
        return planner.result()
    if strategy not in ("naive", "queue"):
        raise ValueError(f"unknown strategy {strategy!r}")

    root = host_graph_from(g)
    root.depth = h.l
    total_weight = float(root.vwgt.sum())
    pe_of = np.zeros(root.n, np.int64)
    stats = {"partition_calls": 0, "levels": [], "strategy": strategy,
             "padded_vertex_work": 0, "real_vertex_work": 0,
             "backend": backend,
             "compile_cache": {"hits": 0, "misses": 0}}
    cache_stats = stats["compile_cache"]
    rec_lock = threading.Lock()

    def record(batchN, realn):
        with rec_lock:
            stats["partition_calls"] += 1
            stats["padded_vertex_work"] += int(batchN)
            stats["real_vertex_work"] += int(realn)

    ctx = (h, eps, preset, seed, total_weight, adaptive, backend, record,
           cache_stats, checkpoint)
    current = [root]
    t0 = time.time()
    while current:
        if checkpoint is not None:
            checkpoint()
        nxt: list[_HostGraph] = []
        leaves = [hg for hg in current if hg.depth == 0]
        for hg in leaves:
            pe_of[hg.orig_ids] = hg.pe_base
        work = [hg for hg in current if hg.depth > 0]
        if not work:
            break
        lvl_t0 = time.time()
        if strategy == "naive":
            produced = _run_naive(work, ctx)
        else:
            produced = _run_queue(work, ctx)
        stats["levels"].append({"graphs": len(work), "seconds": time.time() - lvl_t0})
        nxt.extend(produced)
        current = nxt
    stats["seconds"] = time.time() - t0
    return MultisectionResult(pe_of=pe_of, stats=stats)


def _children_of(hg: _HostGraph, part: np.ndarray, h: Hierarchy) -> list[_HostGraph]:
    d = hg.depth
    arity = h.a[d - 1]
    child_stride = int(np.prod(h.a[: d - 1])) if d > 1 else 1
    return _split(hg, part, arity, d - 1, child_stride, arity)


def _run_naive(work, ctx):
    (h, eps, preset, seed, total_weight, adaptive, backend, record,
     cache_stats, checkpoint) = ctx
    out = []
    for hg in work:
        if checkpoint is not None:
            checkpoint()
        arity = h.a[hg.depth - 1]
        e = _eps_for(hg, h, eps, total_weight, adaptive)
        part = _partition_one(hg, arity, e, preset, seed * 100003 + hg.uid,
                              backend, cache_stats)
        record(_next_pow2(hg.n), hg.n)
        out.extend(_children_of(hg, part, h))
    return out


def _run_queue(work, ctx, workers: int | None = None):
    """PRIORITY QUEUE (Algorithm 2): workers pop the largest pending
    subgraph from a condition-variable-guarded heap; children re-enter the
    queue until only leaves remain. XLA dispatch is asynchronous, so while
    one worker blocks on device results another extracts subgraphs on the
    host — the JAX analogue of the paper's thread groups. No polling: the
    seed's 1 ms sleep-poll loop (and its unreachable ``done.is_set()``
    early-return) is replaced by ``Condition.wait``/``notify_all``.

    Worker count defaults to the host core count clamped to [2, 4]. The
    floor of 2 is deliberate even on a 1-core host: XLA releases the GIL
    while a dispatched program executes, so a second worker keeps host-side
    subgraph extraction overlapping device compute. The ceiling avoids
    oversubscription — XLA:CPU multithreads each program itself, and going
    2 -> 4 workers on a 2-core container measured ~4% SLOWER.
    """
    if workers is None:
        import os
        workers = max(2, min(4, os.cpu_count() or 2))
    (h, eps, preset, seed, total_weight, adaptive, backend, record,
     cache_stats, checkpoint) = ctx
    cv = threading.Condition()
    heap: list[tuple[int, int, _HostGraph]] = []
    out: list[_HostGraph] = []
    pending = [0]   # queued + in-flight tasks, guarded by cv
    errors: list[BaseException] = []

    for hg in work:
        heapq.heappush(heap, (-hg.n, hg.uid, hg))
        pending[0] += 1

    def worker():
        while True:
            with cv:
                while not heap and pending[0] > 0 and not errors:
                    cv.wait()
                if errors or pending[0] == 0:
                    return
                task = heapq.heappop(heap)[2]
            try:
                if checkpoint is not None:
                    checkpoint()  # cooperative cancellation per task
                arity = h.a[task.depth - 1]
                e = _eps_for(task, h, eps, total_weight, adaptive)
                part = _partition_one(task, arity, e, preset,
                                      seed * 100003 + task.uid, backend, cache_stats)
                record(_next_pow2(task.n), task.n)
                children = _children_of(task, part, h)
            except BaseException as exc:  # propagate to the caller
                with cv:
                    errors.append(exc)
                    cv.notify_all()
                return
            with cv:
                pending[0] -= 1
                for c in children:
                    if c.depth > 0:
                        heapq.heappush(heap, (-c.n, c.uid, c))
                        pending[0] += 1
                    else:
                        out.append(c)
                cv.notify_all()

    threads = [threading.Thread(target=worker) for _ in range(workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    return out


STRATEGIES = ("naive", "layer", "bucket", "queue")
