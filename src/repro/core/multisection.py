"""Hierarchical multisection (the paper's §4) with scheduling strategies.

The communication graph is partitioned along the hierarchy
``H = a_1 : ... : a_l`` (top-down: first a_l, then a_{l-1}, ...), with the
adaptive imbalance of Lemma 5.1 applied at every sub-partition, so the final
k-way partition is eps-balanced and the identity mapping solves the mapping
phase.

Scheduling strategies (§4.2-4.5), adapted from C++ threads to JAX/XLA:

* ``naive``   — partition one subgraph at a time (all compute on one task).
* ``layer``   — all subgraphs of one hierarchy level padded to a common
                shape and partitioned by ONE vmapped program (the level
                barrier is the program boundary). Paper: Algorithm 1.
* ``bucket``  — the NON-BLOCKING LAYER analogue: subgraphs of a level are
                grouped into power-of-two size buckets; each bucket is its
                own vmapped program, so small subgraphs do not pay the
                padding (idle-lane) cost of the largest one.
* ``queue``   — the PRIORITY QUEUE analogue: worker threads pop the largest
                pending subgraph from a condition-variable-guarded heap and
                dispatch its partition call (XLA dispatch is asynchronous,
                so one worker's host-side subgraph extraction overlaps
                another's device compute). Paper: Algorithm 2.
* ``device``  — the fully DEVICE-RESIDENT level loop: every level keeps all
                lanes at the ROOT's padded shape, subgraph extraction runs
                on device (graph.split_blocks), the adaptive imbalance is
                evaluated on device (hierarchy.adaptive_epsilon_jnp) and the
                PE labels accumulate in a device buffer — the whole pipeline
                is ONE asynchronous dispatch chain with exactly one
                device->host fetch (the final ``pe_of``) per request.

Single graph representation
---------------------------
All strategies now share the padded device CSR `Graph` as the ONE graph
store. ``bucket``/``layer`` default to the device-resident planner
(``resident=True``): children stay on device in stacked per-group
containers and only a [B]-sized metadata fetch (child n/m/weight — needed
for data-dependent bucket shapes and the f64 imbalance rule) crosses the
bus per level. ``resident=False`` restores the PR-5 host-mirror loop
(`_HostGraph` round-trip per level) — kept as the bitwise reference and
for the naive/queue strategies, where `_HostGraph` survives as a thin
host-side metadata + extraction view.

Planner / executor split
------------------------
The LAYER/BUCKET/DEVICE strategies are expressed as a reusable two-phase
planner so that an external scheduler can interleave work from MANY
in-flight hierarchies (serve/mapper.MappingService):

* :func:`plan_level` turns one hierarchy level's pending subgraphs into
  :class:`PlanGroup`s — pure bookkeeping, no device work. Each group
  carries everything a dispatch needs (members, padded shapes, arity,
  preset/backend/ELL-degree, per-member eps and salts; resident groups
  additionally reference their stacked device batch).
* :func:`execute_group_batch` runs one stacked vmapped dispatch for one or
  MORE groups sharing :attr:`PlanGroup.exec_key` — the cross-request
  coalescing primitive. vmap lanes are independent, so a member's result
  is bit-identical whatever batch it rides in (tested).
* :class:`LevelPlanner` is the level-stepped state machine driving one
  hierarchy: ``plan() -> execute -> advance`` until done. The in-process
  planner path of :func:`hierarchical_multisection` runs on the SAME
  planner, so the direct path and the mapping service share every
  planning decision — the precondition for bit-identical results.

Compile-cache policy
--------------------
Single-subgraph calls go straight to the jitted ``partition``; batched
calls go through :func:`partition.batched_partition`, a process-wide memo
of jitted vmapped wrappers keyed by ``(k, levels, preset, backend,
ell_deg)``. The device-resident split/repack/eps/scatter programs live in
their own memo (:func:`_jit_op`), keyed by static shapes (+ the kernel
backend for programs that dispatch through kernels/ops). Both are shared
across hierarchy levels, strategies and calls. :func:`_note_program`
tracks every distinct XLA partition-program key ``(N, M, batch, k,
levels, preset, backend, ell_deg)``: first sighting in the process =
compile (miss), later sightings = reuse (hit); per-run counts land in
``stats["compile_cache"]``.

Transfer accounting
-------------------
Module-level counters (:func:`transfer_stats` / :func:`reset_transfer_stats`)
record every host<->device array movement the multisection performs:
bulk graph uploads (`_stack_to_device`, `_partition_one`), bulk label /
mirror fetches (``d2h_array_fetches``) and per-level metadata fetches
(``d2h_meta_fetches``). On the ``device`` strategy a request costs exactly
ONE array fetch — the final ``pe_of`` — which the ``device_pipeline``
benchmark and tests assert. (On CPU hosts the "transfer" is a copy; the
counters measure the protocol an accelerator would pay.)

All strategies use salts derived from the subgraph's position in the
hierarchy (not traversal order), so results are reproducible per strategy
— and identical ACROSS strategies up to padding effects (`queue` and
`naive` pad identically, so they produce bit-equal mappings; `bucket` is
bit-equal to `naive` too, resident or not; `device` is bit-equal to its
own host-reference twin, tested).
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .graph import (Graph, assemble_padded, default_ell_deg,
                    padded_csr_indptr, repad_device, split_blocks, take_lanes)
from .hierarchy import Hierarchy, adaptive_epsilon, adaptive_epsilon_jnp
from .partition import (batched_partition, clear_batched_partition_cache,
                        num_levels, partition)
from .refine import resolve_backend
from ..kernels import ops as kops


# ---------------------------------------------------------------------------
# host<->device transfer accounting
# ---------------------------------------------------------------------------

_XFER_LOCK = threading.Lock()


def _zero_xfer() -> dict:
    return {"h2d_bytes": 0, "h2d_transfers": 0,
            "d2h_bytes": 0, "d2h_array_fetches": 0,
            "d2h_meta_bytes": 0, "d2h_meta_fetches": 0}


_XFER = _zero_xfer()


def _acct(**kw) -> None:
    with _XFER_LOCK:
        for key, v in kw.items():
            _XFER[key] += int(v)


def transfer_stats() -> dict:
    """Snapshot of the process-wide transfer counters (see module doc)."""
    with _XFER_LOCK:
        return dict(_XFER)


def reset_transfer_stats() -> None:
    with _XFER_LOCK:
        _XFER.update(_zero_xfer())


# ---------------------------------------------------------------------------
# host-side subgraph extraction (the resident=False reference + naive/queue)
# ---------------------------------------------------------------------------

def _next_pow2(x: int) -> int:
    return 1 << max(int(np.ceil(np.log2(max(x, 1)))), 0)


@dataclasses.dataclass
class _HostGraph:
    """Numpy mirror of a (sub)graph + bookkeeping for the recursion.

    float32/int32 end-to-end — the device arrays are f32/i32, so the old
    f64/i64 up-casts only doubled the residual transfer volume (and i64
    indices past 2^31 are rejected at construction; graph.check_i32_range).
    """

    vwgt: np.ndarray   # [n] f32
    rows: np.ndarray   # [m] i32 directed
    cols: np.ndarray   # [m] i32
    ewgt: np.ndarray   # [m] f32
    orig_ids: np.ndarray  # [n] i32 vertex ids in the ORIGINAL graph
    depth: int         # hierarchy depth (l at the root, 0 at leaves)
    pe_base: int       # PE id offset accumulated along the recursion
    uid: int           # stable id along the hierarchy path (for salts)

    @property
    def n(self) -> int:
        return self.vwgt.shape[0]

    @property
    def m(self) -> int:
        return self.rows.shape[0]

    @property
    def wsum(self) -> float:
        return float(self.vwgt.sum())

    def to_device(self, N: int, M: int) -> Graph:
        """Padded device Graph via the shared CSR builder (exact indptr)."""
        return assemble_padded(self.vwgt, self.rows, self.cols, self.ewgt,
                               self.n, N, M)


def _stack_to_device(members: list[_HostGraph], N: int, M: int) -> Graph:
    """Batched [B, ...] Graph for a bucket — ONE host->device transfer per
    field instead of one per member per field."""
    B = len(members)
    vwgt = np.zeros((B, N), np.float32)
    rows = np.full((B, M), N - 1, np.int32)
    cols = np.full((B, M), N - 1, np.int32)
    ewgt = np.zeros((B, M), np.float32)
    indptr = np.zeros((B, N + 1), np.int32)
    ns = np.zeros((B,), np.int32)
    ms = np.zeros((B,), np.int32)
    for i, hg in enumerate(members):
        m = hg.m
        vwgt[i, : hg.n] = hg.vwgt
        rows[i, :m] = hg.rows
        cols[i, :m] = hg.cols
        ewgt[i, :m] = hg.ewgt
        indptr[i] = padded_csr_indptr(rows[i], m, N)
        ns[i] = hg.n
        ms[i] = m
    _acct(h2d_bytes=vwgt.nbytes + rows.nbytes + cols.nbytes + ewgt.nbytes
          + indptr.nbytes + ns.nbytes + ms.nbytes, h2d_transfers=7)
    return Graph(
        vwgt=jnp.asarray(vwgt),
        rows=jnp.asarray(rows),
        cols=jnp.asarray(cols),
        ewgt=jnp.asarray(ewgt),
        indptr=jnp.asarray(indptr),
        n=jnp.asarray(ns),
        m=jnp.asarray(ms),
    )


def host_graph_from(g: Graph) -> _HostGraph:
    n = int(g.n)
    m = int(g.m)
    _acct(d2h_bytes=4 * (g.N + 3 * g.M), d2h_array_fetches=1,
          d2h_meta_bytes=8, d2h_meta_fetches=1)
    return _HostGraph(
        vwgt=np.asarray(g.vwgt)[:n],
        rows=np.asarray(g.rows)[:m].astype(np.int32, copy=False),
        cols=np.asarray(g.cols)[:m].astype(np.int32, copy=False),
        ewgt=np.asarray(g.ewgt)[:m],
        orig_ids=np.arange(n, dtype=np.int32),
        depth=0,
        pe_base=0,
        uid=0,
    )


def _split(hg: _HostGraph, part: np.ndarray, k: int, child_depth: int,
           stride: int, arity: int) -> list[_HostGraph]:
    """Extract the k induced block subgraphs of ``hg`` under ``part``
    (host reference of graph.split_blocks — bitwise interchangeable)."""
    part = part[: hg.n]
    relabel = np.zeros(hg.n, np.int32)
    children = []
    for b in range(k):
        sel = np.nonzero(part == b)[0]
        relabel[sel] = np.arange(sel.shape[0])
        emask = (part[hg.rows] == b) & (part[hg.cols] == b)
        children.append(
            _HostGraph(
                vwgt=hg.vwgt[sel],
                rows=relabel[hg.rows[emask]],
                cols=relabel[hg.cols[emask]],
                ewgt=hg.ewgt[emask],
                orig_ids=hg.orig_ids[sel],
                depth=child_depth,
                pe_base=hg.pe_base + b * stride,
                uid=hg.uid * arity + b + 1,
            )
        )
    return children


# ---------------------------------------------------------------------------
# the compiled-callable caches
# ---------------------------------------------------------------------------

_SEEN_SHAPES: set[tuple] = set()         # partition program keys ever compiled
_DEVICE_OPS: dict[tuple, Callable] = {}  # split/repack/eps/scatter programs
_EXEC_LOCK = threading.Lock()

# backward-compat alias: the memo itself now lives in core/partition.py so
# every batched-partition consumer shares one cache.
_batched_partition = batched_partition


def _jit_op(key: tuple, fn: Callable) -> Callable:
    """Process-wide memo for the device-resident helper programs (split,
    lane gather/repack, eps, leaf scatter). Keys are static shapes — and
    the kernel backend where the program dispatches through kernels/ops."""
    with _EXEC_LOCK:
        f = _DEVICE_OPS.get(key)
        if f is None:
            f = jax.jit(fn)
            _DEVICE_OPS[key] = f
    return f


def _ell_deg_for(members, backend: str) -> int | None:
    """Static ELL degree cap for a dispatch, from the REAL mean directed
    degree pooled over the member subgraphs: ``ceil(sum m / sum n)``
    (pow2-padded shapes skew the in-jit default by up to 2x — see
    core/refine.py). Taking the MAX of per-member ceil-means, as this used
    to, over-padded mixed buckets and fragmented the jit cache per outlier
    member. None when the xla backend doesn't need it (avoids fragmenting
    the jit cache key)."""
    if backend != "ell":
        return None
    tot_m = sum(m.m for m in members)
    tot_n = max(sum(m.n for m in members), 1)
    mean = (tot_m + tot_n - 1) // tot_n
    return default_ell_deg(1, mean)  # N=1, M=mean -> cap from the real mean


def _note_program(N: int, M: int, batch: int, k: int, levels: int, preset: str,
                  backend: str, ell_deg: int | None, cache_stats: dict) -> None:
    """Track XLA program reuse: the first sighting of a program key in the
    process is a compile (miss), every later one a cache hit."""
    key = (N, M, batch, k, levels, preset, backend, ell_deg,
           kops.kernel_backend())
    with _EXEC_LOCK:
        hit = key in _SEEN_SHAPES
        _SEEN_SHAPES.add(key)
        # increment inside the lock: queue workers call this concurrently
        cache_stats["hits" if hit else "misses"] += 1


def compile_cache_size() -> int:
    with _EXEC_LOCK:
        return len(_SEEN_SHAPES)


def clear_compile_cache() -> None:
    """Drop the memoized callables AND the program-sighting telemetry.

    Call alongside ``jax.clear_caches()`` — that drops the compiled
    executables inside the memoized jit wrappers, so keeping
    ``_SEEN_SHAPES`` would report 'hits' for programs XLA must recompile.
    """
    with _EXEC_LOCK:
        _SEEN_SHAPES.clear()
        _DEVICE_OPS.clear()
    clear_batched_partition_cache()


# ---------------------------------------------------------------------------
# device-resident level state
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _DeviceLevel:
    """One dispatch group's children, resident on device: a stacked
    ``[B, ...]`` Graph plus the [B, N] original-vertex-id view."""

    g: Graph           # stacked children (n/m fields are [B])
    orig: jax.Array    # [B, N] ids into the ROOT graph (pad -> sentinel)
    depth: int


@dataclasses.dataclass
class _LaneRef:
    """Thin host-side metadata view of one device-resident lane — all the
    planner needs (shape keys, eps inputs, salt derivation) without
    touching the arrays. The successor of `_HostGraph` in resident mode;
    ``n``/``m``/``wsum`` stay unset (-1) on the ``device`` strategy where
    planning is shape-oblivious and eps lives on device."""

    level: _DeviceLevel
    lane: int
    depth: int
    pe_base: int
    uid: int
    n: int = -1
    m: int = -1
    wsum: float = 0.0


def _root_op(Ns: int, Ms: int, N0: int, M0: int) -> Callable:
    """g -> ([1,...] repadded batch, [1, N0] orig ids, f32 total weight)."""
    def run(g: Graph):
        g2 = repad_device(g, N0, M0)
        ar = jnp.arange(N0, dtype=jnp.int32)
        orig = jnp.where(ar < g2.n, ar, g2.n)  # sentinel = n (spare pe slot)
        batch = jax.tree_util.tree_map(lambda a: a[None], g2)
        return batch, orig[None], jnp.sum(g2.vwgt)
    return _jit_op(("root", Ns, Ms, N0, M0), run)


def _split_op(B: int, N: int, M: int, arity: int) -> Callable:
    """[B]-lane batch -> [B*arity]-lane children (+ orig ids + weights)."""
    def run(gb: Graph, parts, ob, sent):
        ch, co, ws = jax.vmap(
            lambda g1, p1, o1: split_blocks(g1, p1, o1, arity, sent)
        )(gb, parts, ob)
        flat = lambda a: a.reshape((B * arity,) + a.shape[2:])
        return (jax.tree_util.tree_map(flat, ch), flat(co), flat(ws))
    return _jit_op(("split", B, N, M, arity, kops.kernel_backend()), run)


def _gather_op(Ns: int, Ms: int, Nd: int, Md: int, nsel: int) -> Callable:
    """Select ``nsel`` lanes of a [B,...] container and repad to (Nd, Md)
    — how resident bucket/layer groups assemble their dispatch batches."""
    def run(gb: Graph, ob, sel, sent):
        sub = take_lanes(gb, sel)
        sub = jax.vmap(lambda g1: repad_device(g1, Nd, Md))(sub)
        o = jnp.take(ob, sel, axis=0)
        if Nd <= Ns:
            o = o[:, :Nd]
        else:
            pad = jnp.broadcast_to(sent, (nsel, Nd - Ns)).astype(jnp.int32)
            o = jnp.concatenate([o, pad], axis=1)
        return sub, o
    return _jit_op(("gather", Ns, Ms, Nd, Md, nsel), run)


def _eps_op(B: int, k: int, k_sub: int, depth: int, eps: float,
            adaptive: bool) -> Callable:
    """[B] f32 subgraph weights -> [B] f32 adaptive eps (Lemma 5.1).

    ONE program serves both the device path (fed split_blocks weights) and
    the host-reference path (fed numpy f32 sums) so their eps bits match.
    """
    def run(wsums, total):
        if not adaptive or depth <= 0:
            return jnp.full((B,), eps, jnp.float32)
        return adaptive_epsilon_jnp(eps, total, wsums, k, k_sub, depth)
    return _jit_op(("eps", B, k, k_sub, depth, float(eps), bool(adaptive)), run)


def _scatter_op(B: int, N: int) -> Callable:
    """Leaf write: pe[orig[b, v]] = base[b] + part[b, v] (pads hit the
    sentinel slot; the buffer has one spare entry for exactly that)."""
    def run(pe, ob, parts, bases):
        vals = bases[:, None] + parts[:, :N].astype(jnp.int32)
        return pe.at[ob.reshape(-1)].set(vals.reshape(-1), mode="drop")
    return _jit_op(("scatter", B, N), run)


# ---------------------------------------------------------------------------
# the level planner (shared by the in-process strategies and serve/mapper)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PlanGroup:
    """One bucket dispatch planned from a single hierarchy's current level.

    Host groups (``resident=False``) are pure bookkeeping — members are
    `_HostGraph`s stacked/uploaded at dispatch time. Resident groups carry
    their stacked device ``batch`` (built by the planner from the previous
    level's on-device children) plus the [B, N] original-id view; their
    ``eps`` may live on device (``eps_dev``) for the ``device`` strategy.
    ``eps``/``salts`` are per-member (position-derived, so independent of
    which batch the member eventually rides in).
    """

    members: list
    N: int                # padded vertex shape of the dispatch
    M: int                # padded edge shape
    arity: int            # k of each member's sub-partition
    levels: int           # static coarsening depth for (N, arity)
    preset: str
    backend: str
    deg: int | None       # static ELL degree cap (None for xla)
    eps: list[float]
    salts: list[int]
    resident: bool = False
    batch: Graph | None = None           # [B, ...] device input (resident)
    batch_orig: jax.Array | None = None  # [B, N] root ids (resident)
    eps_dev: jax.Array | None = None     # [B] f32 device eps (device strategy)

    @property
    def exec_key(self) -> tuple:
        """Groups with equal keys run the same compiled executable and may
        be stacked into ONE dispatch (the cross-request coalescing key)."""
        return (self.N, self.M, self.arity, self.levels, self.preset,
                self.backend, self.deg)

    def eps_array(self) -> jax.Array:
        if self.eps_dev is not None:
            return self.eps_dev
        return jnp.asarray(self.eps, jnp.float32)

    def salts_array(self) -> jax.Array:
        return jnp.asarray(self.salts, jnp.int32)

    def graph_batch(self) -> Graph:
        if self.resident:
            return self.batch
        return _stack_to_device(self.members, self.N, self.M)


def plan_level(work: list, h: Hierarchy, eps: float, preset: str,
               seed: int, total_weight: float, adaptive: bool, backend: str,
               bucketed: bool = True) -> list[PlanGroup]:
    """Group one level's pending subgraphs into dispatch units.

    ``bucketed=True`` is the BUCKET strategy (power-of-two shape buckets);
    ``False`` is LAYER (one group per arity, padded to the level max).
    Members may be `_HostGraph`s or `_LaneRef`s — planning only reads the
    ``n/m/depth/uid/wsum`` metadata either exposes.
    """
    groups: dict[tuple[int, int, int], list] = {}
    for hg in work:
        if bucketed:
            key_n = _next_pow2(hg.n)
            key_m = _next_pow2(max(hg.m, 1))
        else:
            key_n = key_m = 0  # one group per arity; padded to layer max below
        arity = h.a[hg.depth - 1]
        groups.setdefault((key_n, key_m, arity), []).append(hg)

    out = []
    for (kn, km, arity), members in groups.items():
        N = kn or _next_pow2(max(m.n for m in members))
        M = km or _next_pow2(max(max(m.m, 1) for m in members))
        out.append(PlanGroup(
            members=members, N=N, M=M, arity=arity,
            levels=num_levels(N, arity), preset=preset, backend=backend,
            deg=_ell_deg_for(members, backend),
            eps=[_eps_for(m, h, eps, total_weight, adaptive) for m in members],
            salts=[seed * 100003 + m.uid for m in members],
        ))
    return out


def dispatch_group_batch(groups: list[PlanGroup], cache_stats: dict,
                         pad_batch_pow2: bool = False) -> tuple:
    """Stack and dispatch ONE vmapped call for PlanGroups sharing
    ``exec_key``; returns an opaque handle for :func:`fetch_group_batch`.

    XLA dispatch is asynchronous, so a scheduler can dispatch every merged
    set of a level before fetching any — host-side stacking of the next
    set overlaps device compute of the previous one (serve/mapper).
    Host groups upload their stacked members; resident groups contribute
    their on-device batches directly (a device-side concat when several
    groups merge) — coalescing works across the two kinds.

    ``pad_batch_pow2`` replicates the last lane up to the next power of
    two (spare lanes dropped): the service uses it to bound the number of
    distinct batch widths XLA must compile for, at the cost of idle-lane
    compute on ragged batches.
    """
    key = groups[0].exec_key
    for gr in groups[1:]:
        if gr.exec_key != key:
            raise ValueError(f"mismatched exec keys: {gr.exec_key} != {key}")
    g0 = groups[0]
    B = sum(len(gr.members) for gr in groups)
    Bp = _next_pow2(B) if pad_batch_pow2 else B
    _note_program(g0.N, g0.M, Bp, g0.arity, g0.levels, g0.preset, g0.backend,
                  g0.deg, cache_stats)
    fn = batched_partition(g0.arity, g0.levels, g0.preset, g0.backend, g0.deg)

    batches = [gr.graph_batch() for gr in groups]
    eps_parts = [gr.eps_array() for gr in groups]
    salt_parts = [gr.salts_array() for gr in groups]
    if len(groups) == 1:
        batch, eps, salts = batches[0], eps_parts[0], salt_parts[0]
    else:
        cat = lambda xs: jnp.concatenate(xs, axis=0)
        batch = jax.tree_util.tree_map(lambda *a: cat(a), *batches)
        eps = cat(eps_parts)
        salts = cat(salt_parts)
    if Bp > B:
        rep = lambda a: jnp.concatenate(
            [a, jnp.repeat(a[-1:], Bp - B, axis=0)], axis=0)
        batch = jax.tree_util.tree_map(rep, batch)
        eps = rep(eps)
        salts = rep(salts)
    parts = fn(batch, eps, salts)
    return parts, groups


def fetch_group_batch(handle: tuple) -> list:
    """Resolve a dispatched batch into one ``[B_i, N]`` array per group.

    Host groups are fetched to numpy (the d2h sync point); resident groups
    get lazy device slices — no transfer, the labels feed the next level's
    on-device split."""
    parts, groups = handle
    parts_np = None
    out = []
    ofs = 0
    for gr in groups:
        B = len(gr.members)
        if gr.resident:
            out.append(parts[ofs: ofs + B])
        else:
            if parts_np is None:
                parts_np = np.asarray(parts)
                _acct(d2h_bytes=parts_np.nbytes, d2h_array_fetches=1)
            out.append(parts_np[ofs: ofs + B])
        ofs += B
    return out


def execute_group_batch(groups: list[PlanGroup], cache_stats: dict,
                        pad_batch_pow2: bool = False) -> list:
    """Dispatch + fetch in one call (the in-process strategies' path).

    Returns one ``[B_i, N]`` partition array per input group, in order.
    Because vmap lanes are independent, each member's partition is
    bit-identical to what a solo dispatch would produce — so coalescing
    groups from different requests cannot change any request's result.
    """
    return fetch_group_batch(
        dispatch_group_batch(groups, cache_stats, pad_batch_pow2))


_PLANNER_STRATEGIES = ("layer", "bucket", "device")


class LevelPlanner:
    """Level-stepped multisection state machine for ONE hierarchy.

    Alternates ``plan()`` (PlanGroups for the current level; pure host
    work) with ``advance(results)`` (feed partition results, split
    children, step to the next level) until ``plan()`` returns ``[]``.
    The executor is external, so a scheduler holding several planners can
    merge their same-``exec_key`` groups into shared dispatches
    (serve/mapper.MappingService) — while the in-process path executes
    each group alone, yielding identical per-member programs.

    ``resident=True`` (default for all planner strategies) keeps every
    level's subgraphs on device: ``advance`` feeds the partition labels
    straight into the on-device split, and only metadata crosses the bus —
    nothing at all on the ``device`` strategy, a [B]-sized child-size/
    weight fetch on bucket/layer (their bucket shapes are data-dependent).
    ``resident=False`` is the PR-5 host-mirror loop, planning-identical
    and bit-identical in its results (the regression reference).
    """

    def __init__(self, g: Graph, h: Hierarchy, eps: float = 0.03,
                 preset: str = "eco", seed: int = 0, adaptive: bool = True,
                 backend: str = "auto", bucketed: bool = True,
                 checkpoint: Callable[[], None] | None = None,
                 strategy: str | None = None, resident: bool | None = None):
        if strategy is None:
            strategy = "bucket" if bucketed else "layer"
        if strategy not in _PLANNER_STRATEGIES:
            raise ValueError(f"unknown planner strategy {strategy!r}")
        self.h = h
        self.checkpoint = checkpoint
        self.eps = eps
        self.preset = preset
        self.seed = seed
        self.adaptive = adaptive
        self.backend = resolve_backend(backend)
        self.strategy = strategy
        self.bucketed = strategy == "bucket"
        self.resident = True if resident is None else bool(resident)
        self.stats = {"partition_calls": 0, "levels": [],
                      "strategy": strategy, "resident": self.resident,
                      "padded_vertex_work": 0, "real_vertex_work": 0,
                      "backend": self.backend,
                      "compile_cache": {"hits": 0, "misses": 0}}
        self.cache_stats = self.stats["compile_cache"]
        self._t0 = time.time()
        self._level_t0: float | None = None
        self._groups: list[PlanGroup] | None = None
        self._done = False
        self._work: list = []
        self.pe_of: np.ndarray | None = None
        if self.resident:
            self._init_resident(g)
        else:
            self._init_host(g)

    # -- construction ------------------------------------------------------

    def _init_host(self, g: Graph) -> None:
        root = host_graph_from(g)
        root.depth = self.h.l
        self.n_root = root.n
        self.N0 = _next_pow2(root.n)
        self.M0 = _next_pow2(max(root.m, 1))
        self.total_weight = root.wsum
        self._tw_f32 = jnp.float32(np.float32(root.vwgt.sum()))
        self._root_deg = _ell_deg_for([root], self.backend)
        self.pe_of = np.zeros(root.n, np.int32)
        self._current: list = [root]

    def _init_resident(self, g: Graph) -> None:
        n_root = int(g.n)
        m_root = int(g.m)
        _acct(d2h_meta_bytes=8, d2h_meta_fetches=1)
        self.n_root = n_root
        self.N0 = _next_pow2(n_root)
        self.M0 = _next_pow2(max(m_root, 1))
        batch, orig, tw = _root_op(g.N, g.M, self.N0, self.M0)(g)
        root_level = _DeviceLevel(g=batch, orig=orig, depth=self.h.l)
        self._sent = batch.n[0]          # spare pe slot for pad writes
        self._pe = jnp.zeros(n_root + 1, jnp.int32)
        self._tw_dev = tw
        self._root_deg = None
        if self.backend == "ell":
            mean = (m_root + max(n_root, 1) - 1) // max(n_root, 1)
            self._root_deg = default_ell_deg(1, mean)
        if self.strategy == "device":
            self.total_weight = None      # never fetched
            d = self.h.l
            self._eps_dev = _eps_op(1, self.h.k, self.h.k, d, self.eps,
                                    self.adaptive)(tw[None], tw)
        else:
            # bucket/layer need host shape keys + the f64 imbalance rule:
            # one scalar metadata fetch, bit-compatible with the host path
            # for integer weights (f32 sums are exact below 2^24).
            self.total_weight = float(tw)
            _acct(d2h_meta_bytes=4, d2h_meta_fetches=1)
        self._current = [_LaneRef(level=root_level, lane=0, depth=self.h.l,
                                  pe_base=0, uid=0, n=n_root, m=m_root,
                                  wsum=self.total_weight or 0.0)]

    # -- the plan/advance cycle -------------------------------------------

    @property
    def done(self) -> bool:
        return self._done

    def plan(self) -> list[PlanGroup]:
        """PlanGroups for the current level; ``[]`` once fully partitioned.
        Idempotent until ``advance`` consumes the results."""
        if self._done:
            return []
        if self._groups is None:
            # cooperative cancellation checkpoint: a deadline/shutdown hook
            # may abort here, BETWEEN levels, instead of after the full
            # pipeline (serve/mapper deadlines, close(wait=False)).
            if self.checkpoint is not None:
                self.checkpoint()
            if not self.resident:
                for hg in self._current:
                    if hg.depth == 0:
                        self.pe_of[hg.orig_ids] = hg.pe_base
            self._work = [w for w in self._current if w.depth > 0]
            if not self._work:
                self._finish()
                return []
            self._level_t0 = time.time()
            if self.strategy == "device":
                self._groups = self._plan_root_shape()
            else:
                self._groups = plan_level(
                    self._work, self.h, self.eps, self.preset, self.seed,
                    self.total_weight, self.adaptive, self.backend,
                    self.bucketed)
                if self.resident:
                    for gr in self._groups:
                        gr.resident = True
                        gr.batch, gr.batch_orig = self._gather_group(gr)
        return self._groups

    def _plan_root_shape(self) -> list[PlanGroup]:
        """The ``device`` strategy's fixed-shape schedule: every level is
        ONE group at the root's (N0, M0) padding — lane count, uids and
        salts are host-deterministic, so planning needs no device data."""
        work = self._work
        d = work[0].depth
        arity = self.h.a[d - 1]
        gr = PlanGroup(
            members=list(work), N=self.N0, M=self.M0, arity=arity,
            levels=num_levels(self.N0, arity), preset=self.preset,
            backend=self.backend, deg=self._root_deg,
            eps=[], salts=[self.seed * 100003 + w.uid for w in work])
        if self.resident:
            lvl = work[0].level
            gr.resident = True
            gr.batch = lvl.g
            gr.batch_orig = lvl.orig
            gr.eps_dev = self._eps_dev
        else:
            # host-reference twin: same eps PROGRAM as the device path, fed
            # numpy f32 sums — identical inputs give identical eps bits.
            wsums = jnp.asarray(
                np.asarray([w.wsum for w in work], np.float32))
            k_sub = int(np.prod(self.h.a[:d]))
            fn = _eps_op(len(work), self.h.k, k_sub, d, self.eps,
                         self.adaptive)
            gr.eps = [float(x) for x in np.asarray(fn(wsums, self._tw_f32))]
        return [gr]

    def _gather_group(self, gr: PlanGroup) -> tuple[Graph, jax.Array]:
        """Assemble a resident bucket/layer group's [B,...] dispatch batch
        from the per-container children (runs of members sharing a
        container become one lane-take + repad program each)."""
        batches: list[Graph] = []
        origs: list[jax.Array] = []
        i = 0
        members = gr.members
        while i < len(members):
            lv = members[i].level
            j = i
            lanes = []
            while j < len(members) and members[j].level is lv:
                lanes.append(members[j].lane)
                j += 1
            # lane widths, NOT Graph.N/M: those read shape[0], which on a
            # stacked [B, ...] container is the batch axis.
            Ns, Ms = lv.g.vwgt.shape[-1], lv.g.rows.shape[-1]
            fn = _gather_op(Ns, Ms, gr.N, gr.M, len(lanes))
            sub, o = fn(lv.g, lv.orig, jnp.asarray(lanes, jnp.int32),
                        self._sent)
            batches.append(sub)
            origs.append(o)
            i = j
        if len(batches) == 1:
            return batches[0], origs[0]
        cat = lambda *a: jnp.concatenate(a, axis=0)
        return (jax.tree_util.tree_map(cat, *batches),
                jnp.concatenate(origs, axis=0))

    def advance(self, results: list) -> None:
        """Feed one ``[B_i, N]`` partition array per group from ``plan()``."""
        groups = self.plan()
        if len(results) != len(groups):
            raise ValueError(f"expected {len(groups)} results, got {len(results)}")
        if self.resident:
            self._advance_resident(groups, results)
        else:
            nxt: list[_HostGraph] = []
            for gr, parts in zip(groups, results):
                parts = np.asarray(parts)
                for i, hg in enumerate(gr.members):
                    self._record(gr.N, hg.n)
                    nxt.extend(_children_of(hg, parts[i][: hg.n], self.h))
            self._current = nxt
        self.stats["levels"].append(
            {"graphs": len(self._work), "seconds": time.time() - self._level_t0})
        self._groups = None

    def _advance_resident(self, groups: list[PlanGroup], results: list) -> None:
        nxt: list[_LaneRef] = []
        for gr, parts in zip(groups, results):
            B = len(gr.members)
            d = gr.members[0].depth
            arity = gr.arity
            self.stats["partition_calls"] += B
            self.stats["padded_vertex_work"] += B * gr.N
            if self.strategy == "device":
                # each level's lanes partition a disjoint cover of the root
                self.stats["real_vertex_work"] += self.n_root
            else:
                self.stats["real_vertex_work"] += sum(r.n for r in gr.members)
            if d == 1:
                bases = jnp.asarray([r.pe_base for r in gr.members], jnp.int32)
                self._pe = _scatter_op(B, gr.N)(
                    self._pe, gr.batch_orig, parts, bases)
                continue
            stride = int(np.prod(self.h.a[: d - 1]))
            ch, co, ws = _split_op(B, gr.N, gr.M, arity)(
                gr.batch, parts, gr.batch_orig, self._sent)
            lvl = _DeviceLevel(g=ch, orig=co, depth=d - 1)
            if self.strategy == "device":
                nxt.extend(
                    _LaneRef(level=lvl, lane=i * arity + b, depth=d - 1,
                             pe_base=r.pe_base + b * stride,
                             uid=r.uid * arity + b + 1)
                    for i, r in enumerate(gr.members) for b in range(arity))
                k_sub = int(np.prod(self.h.a[: d - 1]))
                self._eps_dev = _eps_op(B * arity, self.h.k, k_sub, d - 1,
                                        self.eps, self.adaptive)(
                    ws, self._tw_dev)
            else:
                # bucket/layer shapes are data-dependent: fetch the child
                # metadata (sizes + weights), NOT the arrays.
                ns = np.asarray(ch.n)
                ms = np.asarray(ch.m)
                wv = np.asarray(ws)
                _acct(d2h_meta_bytes=ns.nbytes + ms.nbytes + wv.nbytes,
                      d2h_meta_fetches=3)
                for i, r in enumerate(gr.members):
                    for b in range(arity):
                        j = i * arity + b
                        nxt.append(_LaneRef(
                            level=lvl, lane=j, depth=d - 1,
                            pe_base=r.pe_base + b * stride,
                            uid=r.uid * arity + b + 1,
                            n=int(ns[j]), m=int(ms[j]), wsum=float(wv[j])))
        self._current = nxt

    def _record(self, batchN: int, realn: int) -> None:
        self.stats["partition_calls"] += 1
        self.stats["padded_vertex_work"] += int(batchN)
        self.stats["real_vertex_work"] += int(realn)

    def _finish(self) -> None:
        if not self._done:
            self._done = True
            self.stats["seconds"] = time.time() - self._t0

    def result(self) -> "MultisectionResult":
        if not self._done:
            raise RuntimeError("planner has pending levels")
        if self.resident and self.pe_of is None:
            # THE device->host sync point: one fetch per request.
            pe = np.asarray(self._pe[: self.n_root])
            _acct(d2h_bytes=pe.nbytes, d2h_array_fetches=1)
            self.pe_of = pe
        return MultisectionResult(pe_of=self.pe_of, stats=self.stats)


# ---------------------------------------------------------------------------
# the multisection driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MultisectionResult:
    pe_of: np.ndarray            # [n] i32 PE assignment (the mapping Pi)
    stats: dict                   # timing / scheduling telemetry


PartitionFn = Callable[..., jax.Array]


def _eps_for(hg, h: Hierarchy, eps: float, total_weight: float,
             adaptive: bool) -> float:
    if not adaptive:
        return eps
    d = hg.depth
    k_sub = int(np.prod(h.a[:d])) if d > 0 else 1
    return adaptive_epsilon(eps, total_weight, hg.wsum, h.k, k_sub, d)


def _partition_one(hg: _HostGraph, k: int, eps_val: float, preset: str,
                   salt: int, backend: str, cache_stats: dict,
                   pad_n: int | None = None, pad_m: int | None = None) -> np.ndarray:
    N = pad_n or _next_pow2(hg.n)
    M = pad_m or _next_pow2(max(hg.m, 1))
    lv = num_levels(N, k)
    deg = _ell_deg_for([hg], backend)
    _note_program(N, M, 0, k, lv, preset, backend, deg, cache_stats)
    g = hg.to_device(N, M)
    _acct(h2d_bytes=4 * (N + 3 * M + N + 1 + 2), h2d_transfers=7)
    part = np.asarray(partition(g, k, jnp.float32(eps_val), lv, preset,
                                jnp.int32(salt), backend, deg))
    _acct(d2h_bytes=part.nbytes, d2h_array_fetches=1)
    return part[: hg.n]


def _coarsen_telemetry_stats(g: Graph, h: Hierarchy) -> dict:
    """``stats["coarsen"]``: per-level shrink telemetry of the ROOT graph's
    coarsening cascade (the depth/degree the first sub-partition uses),
    measured with :func:`coarsen.coarsen_cascade` — O(1) device memory in
    the level count, one ``2*levels``-scalar fetch."""
    from .coarsen import coarsen_cascade
    n, m = int(g.n), int(g.m)
    arity = h.a[h.l - 1] if h.l > 0 else h.k
    lv = num_levels(n, arity)
    deg = default_ell_deg(n, max(m, 1))
    ns, ms = coarsen_cascade(g, lv, ell_deg=deg)
    ns = np.asarray(ns)
    ms = np.asarray(ms)
    _acct(d2h_meta_bytes=ns.nbytes + ms.nbytes, d2h_meta_fetches=2)
    per = []
    prev = n
    for i in range(lv):
        ni = int(ns[i])
        per.append({"n": ni, "m": int(ms[i]),
                    "shrink": round(prev / max(ni, 1), 4)})
        prev = ni
    return {"levels": lv, "ell_deg": deg, "rounds": 3, "per_level": per}


def hierarchical_multisection(
    g: Graph,
    h: Hierarchy,
    eps: float = 0.03,
    preset: str = "eco",
    strategy: str = "bucket",
    seed: int = 0,
    adaptive: bool = True,
    backend: str = "auto",
    checkpoint: Callable[[], None] | None = None,
    resident: bool | None = None,
    coarsen_telemetry: bool = False,
) -> MultisectionResult:
    """Partition ``g`` along ``h`` and return the (identity) mapping.

    ``checkpoint`` is an optional cooperative-cancellation hook invoked
    between levels (and before each naive/queue task); raising inside it
    aborts the multisection — the mechanism behind service deadlines.
    ``resident`` applies to the planner strategies (layer/bucket/device):
    ``None``/``True`` keeps the level loop on device, ``False`` forces the
    host-mirror reference loop (bit-identical results either way).
    ``coarsen_telemetry`` additionally runs the root graph's coarsening
    cascade for its per-level sizes (``stats["coarsen"]``; costs one extra
    device pass, never changes the mapping).
    """
    backend = resolve_backend(backend)
    coarsen_stats = (_coarsen_telemetry_stats(g, h)
                     if coarsen_telemetry else None)
    if strategy in _PLANNER_STRATEGIES:
        # the planner path: identical planning to serve/mapper, each group
        # executed alone (no cross-request members to coalesce here).
        planner = LevelPlanner(g, h, eps=eps, preset=preset, seed=seed,
                               adaptive=adaptive, backend=backend,
                               strategy=strategy, resident=resident,
                               checkpoint=checkpoint)
        while True:
            groups = planner.plan()
            if not groups:
                break
            planner.advance([execute_group_batch([gr], planner.cache_stats)[0]
                             for gr in groups])
        res = planner.result()
        if coarsen_stats is not None:
            res.stats["coarsen"] = coarsen_stats
        return res
    if strategy not in ("naive", "queue"):
        raise ValueError(f"unknown strategy {strategy!r}")
    if resident is not None:
        # naive/queue run entirely on the host path; silently ignoring a
        # residency request would let e.g. a shadow-verification caller
        # believe it exercised the device pipeline when it never existed.
        raise ValueError(f"resident= applies only to the planner strategies "
                         f"{_PLANNER_STRATEGIES}; strategy {strategy!r} has "
                         f"no device-resident variant")

    root = host_graph_from(g)
    root.depth = h.l
    total_weight = root.wsum
    pe_of = np.zeros(root.n, np.int32)
    stats = {"partition_calls": 0, "levels": [], "strategy": strategy,
             "padded_vertex_work": 0, "real_vertex_work": 0,
             "backend": backend,
             "compile_cache": {"hits": 0, "misses": 0}}
    if coarsen_stats is not None:
        stats["coarsen"] = coarsen_stats
    cache_stats = stats["compile_cache"]
    rec_lock = threading.Lock()

    def record(batchN, realn):
        with rec_lock:
            stats["partition_calls"] += 1
            stats["padded_vertex_work"] += int(batchN)
            stats["real_vertex_work"] += int(realn)

    ctx = (h, eps, preset, seed, total_weight, adaptive, backend, record,
           cache_stats, checkpoint)
    current = [root]
    t0 = time.time()
    while current:
        if checkpoint is not None:
            checkpoint()
        nxt: list[_HostGraph] = []
        leaves = [hg for hg in current if hg.depth == 0]
        for hg in leaves:
            pe_of[hg.orig_ids] = hg.pe_base
        work = [hg for hg in current if hg.depth > 0]
        if not work:
            break
        lvl_t0 = time.time()
        if strategy == "naive":
            produced = _run_naive(work, ctx)
        else:
            produced = _run_queue(work, ctx)
        stats["levels"].append({"graphs": len(work), "seconds": time.time() - lvl_t0})
        nxt.extend(produced)
        current = nxt
    stats["seconds"] = time.time() - t0
    return MultisectionResult(pe_of=pe_of, stats=stats)


def _children_of(hg: _HostGraph, part: np.ndarray, h: Hierarchy) -> list[_HostGraph]:
    d = hg.depth
    arity = h.a[d - 1]
    child_stride = int(np.prod(h.a[: d - 1])) if d > 1 else 1
    return _split(hg, part, arity, d - 1, child_stride, arity)


def _run_naive(work, ctx):
    (h, eps, preset, seed, total_weight, adaptive, backend, record,
     cache_stats, checkpoint) = ctx
    out = []
    for hg in work:
        if checkpoint is not None:
            checkpoint()
        arity = h.a[hg.depth - 1]
        e = _eps_for(hg, h, eps, total_weight, adaptive)
        part = _partition_one(hg, arity, e, preset, seed * 100003 + hg.uid,
                              backend, cache_stats)
        record(_next_pow2(hg.n), hg.n)
        out.extend(_children_of(hg, part, h))
    return out


def _run_queue(work, ctx, workers: int | None = None):
    """PRIORITY QUEUE (Algorithm 2): workers pop the largest pending
    subgraph from a condition-variable-guarded heap; children re-enter the
    queue until only leaves remain. XLA dispatch is asynchronous, so while
    one worker blocks on device results another extracts subgraphs on the
    host — the JAX analogue of the paper's thread groups. No polling: the
    seed's 1 ms sleep-poll loop (and its unreachable ``done.is_set()``
    early-return) is replaced by ``Condition.wait``/``notify_all``.

    Worker count defaults to the host core count clamped to [2, 4]. The
    floor of 2 is deliberate even on a 1-core host: XLA releases the GIL
    while a dispatched program executes, so a second worker keeps host-side
    subgraph extraction overlapping device compute. The ceiling avoids
    oversubscription — XLA:CPU multithreads each program itself, and going
    2 -> 4 workers on a 2-core container measured ~4% SLOWER.
    """
    if workers is None:
        import os
        workers = max(2, min(4, os.cpu_count() or 2))
    (h, eps, preset, seed, total_weight, adaptive, backend, record,
     cache_stats, checkpoint) = ctx
    cv = threading.Condition()
    heap: list[tuple[int, int, _HostGraph]] = []
    out: list[_HostGraph] = []
    pending = [0]   # queued + in-flight tasks, guarded by cv
    errors: list[BaseException] = []

    for hg in work:
        heapq.heappush(heap, (-hg.n, hg.uid, hg))
        pending[0] += 1

    def worker():
        while True:
            with cv:
                while not heap and pending[0] > 0 and not errors:
                    cv.wait()
                if errors or pending[0] == 0:
                    return
                task = heapq.heappop(heap)[2]
            try:
                if checkpoint is not None:
                    checkpoint()  # cooperative cancellation per task
                arity = h.a[task.depth - 1]
                e = _eps_for(task, h, eps, total_weight, adaptive)
                part = _partition_one(task, arity, e, preset,
                                      seed * 100003 + task.uid, backend, cache_stats)
                record(_next_pow2(task.n), task.n)
                children = _children_of(task, part, h)
            except BaseException as exc:  # propagate to the caller
                with cv:
                    errors.append(exc)
                    cv.notify_all()
                return
            with cv:
                pending[0] -= 1
                for c in children:
                    if c.depth > 0:
                        heapq.heappush(heap, (-c.n, c.uid, c))
                        pending[0] += 1
                    else:
                        out.append(c)
                cv.notify_all()

    threads = [threading.Thread(target=worker) for _ in range(workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    return out


STRATEGIES = ("naive", "layer", "bucket", "queue", "device")
