"""Initial partition of the coarsest graph: greedy graph growing + LP polish.

Seeds are index-strided (generators and contraction preserve locality in id
order), then blocks grow by repeatedly admitting the unassigned vertices
with the strongest connectivity to each block, under capacity caps. Any
leftover (disconnected) vertices fall to the lightest block, then a
rebalanced LP pass polishes the result. Deterministic given ``salt``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .graph import Graph, block_weights, edge_mask, vertex_mask
from .refine import _vhash, lp_refine, rebalance


@functools.partial(jax.jit, static_argnames=("k", "grow_rounds", "polish_rounds",
                                             "backend", "ell_deg"))
def initial_partition(
    g: Graph,
    k: int,
    Lmax: jax.Array,
    salt: int = 0,
    grow_rounds: int = 24,
    polish_rounds: int = 6,
    backend: str = "auto",
    ell_deg: int | None = None,
) -> jax.Array:
    N = g.N
    idx = jnp.arange(N, dtype=jnp.int32)
    vmask = vertex_mask(g)
    emask = edge_mask(g)
    n = jnp.maximum(g.n, 1)

    # --- seeds: k index-strided real vertices, hash-rotated by salt --------
    offset = (_vhash(1, salt)[0] % jnp.uint32(97)).astype(jnp.int32)
    seed_pos = ((jnp.arange(k, dtype=jnp.int32) * n) // k + offset) % n
    part = jnp.full((N,), k, jnp.int32)  # k == "unassigned"
    part = part.at[seed_pos].set(jnp.arange(k, dtype=jnp.int32))
    part = jnp.where(vmask, part, k)

    # --- greedy growth ------------------------------------------------------
    def grow(r, part):
        assigned = part < k
        pcols = jnp.where(emask & assigned[g.cols], part[g.cols], k)
        flat = g.rows * (k + 1) + pcols
        w = jnp.where(emask, g.ewgt, 0.0)
        conn = jax.ops.segment_sum(w, flat, num_segments=g.N * (k + 1)).reshape(g.N, k + 1)[:, :k]
        W = jax.ops.segment_sum(jnp.where(assigned & vmask, g.vwgt, 0.0), jnp.where(assigned, part, 0), num_segments=k)
        fits = (W[None, :] + g.vwgt[:, None]) <= Lmax
        score = jnp.where(fits, conn, -jnp.inf)
        best = jnp.argmax(score, axis=1).astype(jnp.int32)
        sbest = jnp.max(score, axis=1)
        cand = vmask & ~assigned & (sbest > 0.0)
        # capacity prefix per target block (strongest connections first)
        order = jnp.argsort(jnp.where(cand, -sbest, jnp.inf), stable=True)
        tgt_s = best[order]
        cand_s = cand[order]
        w_s = jnp.where(cand_s, g.vwgt[order], 0.0)
        inflow = jnp.cumsum(jax.nn.one_hot(tgt_s, k, dtype=jnp.float32) * w_s[:, None], axis=0)
        ok_s = cand_s & (jnp.take_along_axis(inflow, tgt_s[:, None], axis=1)[:, 0] <= jnp.maximum(Lmax - W, 0.0)[tgt_s])
        accept = jnp.zeros((N,), bool).at[order].set(ok_s)
        return jnp.where(accept, best, part)

    part = jax.lax.fori_loop(0, grow_rounds, grow, part)

    # --- leftovers -> lightest block with room ------------------------------
    def sweep_leftovers(part):
        assigned = part < k
        W = jax.ops.segment_sum(jnp.where(assigned & vmask, g.vwgt, 0.0), jnp.where(assigned, part, 0), num_segments=k)
        lightest = jnp.argmin(W).astype(jnp.int32)
        todo = vmask & ~assigned
        # admit unassigned in index order until Lmax (approximate: cumsum cap)
        w_cum = jnp.cumsum(jnp.where(todo, g.vwgt, 0.0))
        ok = todo & ((W[lightest] + w_cum) <= jnp.maximum(Lmax, W[lightest] + g.vwgt))
        return jnp.where(ok, lightest, part)

    # a few sweeps (each fills the currently-lightest block)
    part = jax.lax.fori_loop(0, 8, lambda i, p: sweep_leftovers(p), part)
    # anything still left: round-robin by hash (guaranteed assignment)
    left = vmask & (part >= k)
    fallback = (_vhash(N, salt + 5) % jnp.uint32(k)).astype(jnp.int32)
    part = jnp.where(left, fallback, part)
    part = jnp.where(vmask, part, 0)

    # polish with the CALLER's refinement backend: "auto" resolves from the
    # process-wide kernel backend at trace time, so leaving it here would
    # let the coarsest polish silently diverge from the backend the
    # partitioner pinned (breaking cross-backend bitwise invariance).
    part = lp_refine(g, part, k, Lmax, rounds=polish_rounds, salt=salt + 11,
                     backend=backend, ell_deg=ell_deg)
    part = rebalance(g, part, k, Lmax, rounds=6, salt=salt + 17,
                     backend=backend, ell_deg=ell_deg)
    return part
