"""Graph substrate: static-shape CSR graphs as JAX pytrees.

All partitioning kernels operate on `Graph`, a padded CSR representation
with static array shapes so that the same jitted program serves every
subgraph of a hierarchy level (the LAYER/BUCKET scheduling strategies vmap
over stacked `Graph`s).

Conventions
-----------
* Vertices ``0 .. n-1`` are real, ``n .. N-1`` are padding (weight 0).
* Every undirected edge {u, v} is stored twice (u->v and v->u).
* Edge slots ``m .. M-1`` are padding: ``rows == cols == n_pad_anchor`` and
  ``ewgt == 0`` so they are harmless under segment reductions.
* ``rows`` is sorted ascending over the real slots and ``indptr`` is the
  exact CSR prefix over them (``indptr[r]`` = first edge of row ``r``;
  rows >= the real vertex count all point at ``m``). Every constructor in
  this repo funnels through :func:`padded_csr_indptr` /
  :func:`assemble_padded` so the invariant holds at all hierarchy levels.

ELL adjacency (kernel layout)
-----------------------------
:func:`ell_adjacency` derives a padded ``[N, DEG]`` neighbour/weight matrix
pair from the CSR arrays for the Pallas refinement kernels
(``kernels/lp_gain.py``). ``DEG`` is a *static* degree cap chosen host-side
(:func:`default_ell_deg`: twice the mean directed degree, rounded up to a
multiple of 8, clamped to ``ELL_DEG_CAP``). Rows with more than ``DEG``
neighbours are reported in an ``overflow`` mask; callers pick the policy
(the kernel-backed refiner freezes overflow rows so truncated gains can
never admit a bad move; the rebalancer keeps them movable since balance
only needs the exact weight bookkeeping — see ``core/refine.py``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops


class Graph(NamedTuple):
    """Padded CSR graph (pytree; all fields are arrays for vmap-ability)."""

    vwgt: jax.Array    # [N]   f32 vertex weights (0 on padding)
    rows: jax.Array    # [M]   i32 source vertex of each directed edge
    cols: jax.Array    # [M]   i32 target vertex of each directed edge
    ewgt: jax.Array    # [M]   f32 edge weights (0 on padding)
    indptr: jax.Array  # [N+1] i32 CSR row pointers over the padded arrays
    n: jax.Array       # []    i32 number of real vertices
    m: jax.Array       # []    i32 number of real directed edges

    @property
    def N(self) -> int:
        return self.vwgt.shape[0]

    @property
    def M(self) -> int:
        return self.rows.shape[0]

    def total_weight(self) -> jax.Array:
        return jnp.sum(self.vwgt)


def check_i32_range(n: int, m: int) -> None:
    """Overflow guard for the int32 index convention.

    The whole pipeline (device CSR, relabel gathers, `pe_of`) indexes with
    int32; a graph with >= 2^31 vertices or directed edges would silently
    wrap. Every host-side constructor calls this before allocating.
    """
    limit = 2**31
    if n >= limit or m >= limit:
        raise ValueError(
            f"graph exceeds int32 index range: n={n}, m={m} (>= 2^31); "
            "the int32 CSR convention cannot represent it")


def padded_csr_indptr(rows: np.ndarray, m: int, N: int) -> np.ndarray:
    """[N+1] exact CSR prefix over the sorted real directed rows ``rows[:m]``.

    Rows with no edges (including every padding row >= the real vertex
    count) get an empty range; since counts sum to ``m``, all trailing
    entries equal ``m`` — no clamping needed (the old ``np.minimum(indptr,
    m)`` clamp silently flattened offsets whenever a caller passed rows that
    were not already consistent with ``m``).
    """
    counts = np.bincount(np.asarray(rows[:m], np.int64), minlength=N)
    indptr = np.zeros(N + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr


def assemble_padded(
    vwgt: np.ndarray,
    rows: np.ndarray,
    cols: np.ndarray,
    ewgt: np.ndarray,
    n: int,
    N: int,
    M: int,
) -> Graph:
    """Assemble a device `Graph` from REAL (unpadded) host arrays.

    ``rows`` must be sorted ascending; one host->device transfer per field.
    This is the single construction path shared by `from_edges`,
    `pad_graph` and the multisection subgraph extractor.
    """
    m = int(np.asarray(rows).shape[0])
    check_i32_range(max(n, N), max(m, M))
    if N < n or M < m:
        raise ValueError(f"padding too small: N={N}<{n} or M={M}<{m}")
    r = np.full(M, N - 1, np.int32)
    c = np.full(M, N - 1, np.int32)
    w = np.zeros(M, np.float32)
    r[:m] = rows
    c[:m] = cols
    w[:m] = ewgt
    vw = np.zeros(N, np.float32)
    vw[:n] = vwgt
    return Graph(
        vwgt=jnp.asarray(vw),
        rows=jnp.asarray(r),
        cols=jnp.asarray(c),
        ewgt=jnp.asarray(w),
        indptr=jnp.asarray(padded_csr_indptr(r, m, N), jnp.int32),
        n=jnp.asarray(n, jnp.int32),
        m=jnp.asarray(m, jnp.int32),
    )


def from_edges(
    n: int,
    u: np.ndarray,
    v: np.ndarray,
    w: np.ndarray | None = None,
    vwgt: np.ndarray | None = None,
    N: int | None = None,
    M: int | None = None,
) -> Graph:
    """Build a padded CSR `Graph` from an undirected edge list (host-side).

    ``u, v`` are endpoints of undirected edges (each listed once); weights
    default to 1. ``N``/``M`` give the padded sizes (default: exact fit).
    """
    u = np.asarray(u, np.int64)
    v = np.asarray(v, np.int64)
    keep = u != v  # drop self loops
    u, v = u[keep], v[keep]
    w = np.ones(u.shape[0], np.float64) if w is None else np.asarray(w, np.float64)[keep]
    vwgt_np = np.ones(n, np.float64) if vwgt is None else np.asarray(vwgt, np.float64)

    du = np.concatenate([u, v])
    dv = np.concatenate([v, u])
    dw = np.concatenate([w, w])
    m = du.shape[0]

    N = int(N if N is not None else n)
    M = int(M if M is not None else max(m, 1))

    order = np.argsort(du, kind="stable")
    return assemble_padded(vwgt_np, du[order], dv[order], dw[order], n, N, M)


def edge_mask(g: Graph) -> jax.Array:
    """[M] bool — True on real (non-padding) edge slots."""
    return jnp.arange(g.M) < g.m


def vertex_mask(g: Graph) -> jax.Array:
    """[N] bool — True on real vertices."""
    return jnp.arange(g.N) < g.n


def degrees(g: Graph) -> jax.Array:
    return g.indptr[1:] - g.indptr[:-1]


def edge_cut(g: Graph, part: jax.Array) -> jax.Array:
    """Total weight of cut edges (each undirected edge counted once)."""
    cut = (part[g.rows] != part[g.cols]) & edge_mask(g)
    return jnp.sum(jnp.where(cut, g.ewgt, 0.0)) / 2.0


def block_weights(g: Graph, part: jax.Array, k: int) -> jax.Array:
    """[k] f32 — total vertex weight per block (padding contributes 0)."""
    safe = jnp.where(vertex_mask(g), part, 0)
    return jax.ops.segment_sum(g.vwgt, safe, num_segments=k)


# ---------------------------------------------------------------------------
# Synthetic instance generators (the paper's benchmark families, downscaled).
# All host-side numpy, seeded, deterministic.
# ---------------------------------------------------------------------------

def gen_rgg(n: int, seed: int = 0, radius_scale: float = 0.55) -> Graph:
    """Random geometric graph in the unit square (paper: rgg23/rgg24)."""
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 2))
    r = radius_scale * np.sqrt(np.log(max(n, 2)) / n)
    # grid bucketing for near-linear neighbour search
    nb = max(1, int(1.0 / r))
    cell = (pts / (1.0 / nb)).astype(np.int64)
    cell_id = cell[:, 0] * nb + cell[:, 1]
    order = np.argsort(cell_id, kind="stable")
    us, vs = [], []
    starts = {}
    sorted_ids = cell_id[order]
    uniq, first = np.unique(sorted_ids, return_index=True)
    for cid, fi in zip(uniq, first):
        starts[int(cid)] = int(fi)
    bounds = dict(zip(uniq.tolist(), np.append(first[1:], n).tolist()))
    for cx in range(nb):
        for cy in range(nb):
            cid = cx * nb + cy
            if cid not in starts:
                continue
            a = order[starts[cid]:bounds[cid]]
            cand = [a]
            for dx, dy in ((0, 1), (1, -1), (1, 0), (1, 1)):
                nc = (cx + dx) * nb + (cy + dy)
                if 0 <= cx + dx < nb and 0 <= cy + dy < nb and nc in starts:
                    cand.append(order[starts[nc]:bounds[nc]])
            b = np.concatenate(cand)
            d2 = ((pts[a, None, :] - pts[None, b, :]) ** 2).sum(-1)
            ii, jj = np.nonzero(d2 <= r * r)
            uu, vv = a[ii], b[jj]
            keep = uu < vv
            us.append(uu[keep])
            vs.append(vv[keep])
    u = np.concatenate(us) if us else np.zeros(0, np.int64)
    v = np.concatenate(vs) if vs else np.zeros(0, np.int64)
    return from_edges(n, u, v)


def gen_grid(side: int, diag: bool = True) -> Graph:
    """Triangulated grid — a Delaunay-triangulation stand-in (del23/del24)."""
    n = side * side
    idx = np.arange(n).reshape(side, side)
    us = [idx[:, :-1].ravel(), idx[:-1, :].ravel()]
    vs = [idx[:, 1:].ravel(), idx[1:, :].ravel()]
    if diag:
        us.append(idx[:-1, :-1].ravel())
        vs.append(idx[1:, 1:].ravel())
    return from_edges(n, np.concatenate(us), np.concatenate(vs))


def gen_road(n: int, seed: int = 0) -> Graph:
    """Road-network-like graph (paper: eur/deu): sparse, low degree, long
    paths — a perturbed grid with random shortcuts removed/added."""
    side = int(np.sqrt(n))
    n = side * side
    rng = np.random.default_rng(seed)
    idx = np.arange(n).reshape(side, side)
    u = np.concatenate([idx[:, :-1].ravel(), idx[:-1, :].ravel()])
    v = np.concatenate([idx[:, 1:].ravel(), idx[1:, :].ravel()])
    keep = rng.random(u.shape[0]) > 0.1  # drop 10% of edges -> irregularity
    u, v = u[keep], v[keep]
    ns = n // 50  # sparse shortcuts
    su = rng.integers(0, n, ns)
    sv = np.minimum(su + rng.integers(1, side, ns), n - 1)
    return from_edges(n, np.concatenate([u, su]), np.concatenate([v, sv]))


def gen_kron(scale: int, edge_factor: int = 8, seed: int = 0) -> Graph:
    """Kronecker-style power-law graph (complex-network instance family)."""
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    A, B, C = 0.57, 0.19, 0.19
    u = np.zeros(m, np.int64)
    v = np.zeros(m, np.int64)
    for bit in range(scale):
        r1 = rng.random(m)
        r2 = rng.random(m)
        ubit = (r1 > A + B).astype(np.int64)
        vbit = np.where(ubit == 0, (r1 > A).astype(np.int64), (r2 > C / (C + (1 - A - B - C))).astype(np.int64))
        u |= ubit << bit
        v |= vbit << bit
    keep = u != v
    return from_edges(n, u[keep], v[keep])


GENERATORS = {
    "rgg": gen_rgg,
    "grid": lambda n, seed=0: gen_grid(int(np.sqrt(n))),
    "road": gen_road,
    "kron": lambda n, seed=0: gen_kron(max(int(np.log2(max(n, 2))), 4), seed=seed),
}


def pad_graph(g: Graph, N: int, M: int) -> Graph:
    """Host-side re-pad to (N, M) >= current real sizes."""
    n = int(g.n)
    m = int(g.m)
    return assemble_padded(
        np.asarray(g.vwgt)[:n],
        np.asarray(g.rows)[:m],
        np.asarray(g.cols)[:m],
        np.asarray(g.ewgt)[:m],
        n, N, M,
    )


# ---------------------------------------------------------------------------
# Device-resident subgraph extraction (the multisection level loop)
# ---------------------------------------------------------------------------

def repad_device(g: Graph, N2: int, M2: int) -> Graph:
    """Trace-time re-pad of a Graph to static shapes ``(N2, M2)`` — the
    on-device analogue of :func:`pad_graph`. Shrinking drops only padding
    slots (callers guarantee the real counts fit); growing extends with
    the standard pad convention (rows/cols anchored at ``N2-1``, weight 0,
    trailing ``indptr`` = m). Works under vmap (all fields sliced/extended
    along the last axis)."""
    N, M = g.N, g.M

    def fit(a: jax.Array, L: int, fill) -> jax.Array:
        if a.shape[-1] >= L:
            return a[..., :L]
        pad = jnp.full(a.shape[:-1] + (L - a.shape[-1],), fill, a.dtype)
        return jnp.concatenate([a, pad], axis=-1)

    ar_m = jnp.arange(M2, dtype=jnp.int32)
    rows = jnp.where(ar_m < g.m, fit(g.rows, M2, 0), N2 - 1)
    cols = jnp.where(ar_m < g.m, fit(g.cols, M2, 0), N2 - 1)
    ewgt = fit(g.ewgt, M2, 0)        # pads are already 0-weight
    vwgt = fit(g.vwgt, N2, 0)
    # indptr: entries past the real vertex count all equal m, so slicing is
    # exact and extension fills with m.
    ar_n = jnp.arange(N2 + 1, dtype=jnp.int32)
    indptr = jnp.where(ar_n < N + 1, fit(g.indptr, N2 + 1, 0), g.m)
    return Graph(vwgt=vwgt, rows=rows, cols=cols, ewgt=ewgt, indptr=indptr,
                 n=g.n, m=g.m)


def take_lanes(g: Graph, sel: jax.Array) -> Graph:
    """Select lanes of a stacked ``[B, ...]`` Graph: fields indexed along
    axis 0 by ``sel`` (device-side; used to regroup resident children)."""
    return jax.tree_util.tree_map(lambda a: jnp.take(a, sel, axis=0), g)


def split_blocks(g: Graph, part: jax.Array, orig: jax.Array, k: int,
                 sentinel: jax.Array) -> tuple[Graph, jax.Array, jax.Array]:
    """On-device induced-subgraph extraction: the ``k`` block subgraphs of
    ``g`` under ``part``, as ONE stacked ``[k, N]``/``[k, M]`` Graph.

    The device analogue of the host ``_split`` (core/multisection.py) —
    stable-sort-by-block + segment offsets + relabel gather, all static
    shapes so it jits and vmaps over hierarchy-level lanes. Child arrays
    are produced in the SAME order as the host path (stable sort preserves
    vertex/edge order within a block, and the parent's sorted-``rows``
    invariant plus the monotone within-block relabel keeps child rows
    sorted), so the two paths are bitwise interchangeable.

    ``orig`` is the [N] original-vertex-id view of ``g``'s lanes (padding
    slots hold ``sentinel``); ``sentinel`` is propagated to child padding
    so leaf scatters can dump pad writes into a spare ``pe_of`` slot.

    Returns ``(children, child_orig, wsum)``: a stacked Graph whose ``n``/
    ``m`` fields are ``[k]`` per-child real counts, the ``[k, N]`` original
    ids, and the ``[k]`` f32 child vertex-weight sums (for the device-side
    adaptive-imbalance rule).
    """
    N, M = g.N, g.M
    ar_n = jnp.arange(N, dtype=jnp.int32)
    ar_m = jnp.arange(M, dtype=jnp.int32)

    # --- vertices: stable compaction by block --------------------------------
    blk = jnp.where(ar_n < g.n, part[:N].astype(jnp.int32), k)
    counts = jnp.zeros(k + 1, jnp.int32).at[blk].add(1)
    voff = jnp.cumsum(counts) - counts                       # exclusive prefix
    order = jnp.argsort(blk, stable=True).astype(jnp.int32)
    rank = ar_n - voff[blk[order]]
    relabel = jnp.zeros(N, jnp.int32).at[order].set(rank)    # parent -> child id
    vsrc = voff[:k, None] + ar_n[None, :]                    # [k, N] source slots
    v_ok = ar_n[None, :] < counts[:k, None]
    vids = jnp.take(order, jnp.clip(vsrc, 0, N - 1))
    cvwgt = jnp.where(v_ok, kops.gather_rows(g.vwgt, vids), 0.0)
    corig = jnp.where(v_ok, kops.gather_rows(orig, vids), sentinel)

    # --- edges: keep intra-block, relabel endpoints --------------------------
    emask = ar_m < g.m       # padding anchors (N-1) may alias a real vertex
    bu = blk[jnp.clip(g.rows, 0, N - 1)]
    bv = blk[jnp.clip(g.cols, 0, N - 1)]
    eb = jnp.where(emask & (bu == bv) & (bu < k), bu, k)
    ecounts = jnp.zeros(k + 1, jnp.int32).at[eb].add(1)
    eoff = jnp.cumsum(ecounts) - ecounts
    eorder = jnp.argsort(eb, stable=True).astype(jnp.int32)
    esrc = eoff[:k, None] + ar_m[None, :]
    e_ok = ar_m[None, :] < ecounts[:k, None]
    eids = jnp.take(eorder, jnp.clip(esrc, 0, M - 1))
    crows = jnp.where(e_ok, kops.gather_rows(relabel[g.rows], eids), N - 1)
    ccols = jnp.where(e_ok, kops.gather_rows(relabel[g.cols], eids), N - 1)
    cewgt = jnp.where(e_ok, kops.gather_rows(g.ewgt, eids), 0.0)

    # --- exact per-child CSR prefix (matches padded_csr_indptr) --------------
    rtar = jnp.where(e_ok, crows, N)  # row N = dropped (see scatter mode)
    rowcnt = (jnp.zeros((k, N + 1), jnp.int32)
              .at[jnp.arange(k)[:, None], rtar].add(1, mode="drop")[:, :N])
    cindptr = jnp.concatenate(
        [jnp.zeros((k, 1), jnp.int32), jnp.cumsum(rowcnt, axis=1)], axis=1)

    wsum = jax.ops.segment_sum(g.vwgt, blk, num_segments=k + 1)[:k]
    children = Graph(vwgt=cvwgt, rows=crows, cols=ccols, ewgt=cewgt,
                     indptr=cindptr, n=counts[:k], m=ecounts[:k])
    return children, corig, wsum


# ---------------------------------------------------------------------------
# ELL adjacency (the Pallas refinement-kernel layout)
# ---------------------------------------------------------------------------

ELL_DEG_CAP = 64  # hard cap on the static neighbour-matrix width


def default_ell_deg(N: int, M: int, cap: int = ELL_DEG_CAP) -> int:
    """Static degree cap for the [N, DEG] ELL layout.

    Twice the mean directed degree, rounded up to a multiple of 8 (VREG
    sublane alignment), clamped to ``[8, cap]``. Mesh-like instances (the
    paper's main families, max degree ~8) fit entirely; power-law tails
    exceed it and land in the overflow mask.
    """
    avg = (M + max(N, 1) - 1) // max(N, 1)
    return int(min(cap, max(8, ((2 * avg + 7) // 8) * 8)))


def ell_adjacency(g: Graph, deg: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """CSR -> padded ELL, jit-compatible (``deg`` static).

    Returns ``(adj [N, deg], adw [N, deg], overflow [N])`` where ``adj``
    holds neighbour ids (padding slots = N, matching the lp_gain kernel's
    pad convention), ``adw`` the edge weights (0 on padding), and
    ``overflow[u]`` flags vertices whose degree exceeds ``deg`` (their ELL
    row is truncated to the first ``deg`` CSR neighbours).

    Relies on the Graph invariant that ``rows`` is sorted and ``indptr`` is
    its exact prefix, so each edge's within-row position is
    ``index - indptr[row]`` — no argsort needed (cf. ref.csr_to_ell).
    """
    N, M = g.N, g.M
    idx = jnp.arange(M, dtype=jnp.int32)
    emask = idx < g.m
    r = jnp.clip(g.rows, 0, N - 1)
    pos = idx - g.indptr[r]
    valid = emask & (pos >= 0) & (pos < deg)
    slot = jnp.where(valid, r * deg + pos, N * deg)  # N*deg = trimmed slot
    adj = (
        jnp.full((N * deg + 1,), N, jnp.int32)
        .at[slot].set(jnp.where(valid, g.cols, N), mode="drop")[:-1]
    )
    adw = (
        jnp.zeros((N * deg + 1,), g.ewgt.dtype)
        .at[slot].set(jnp.where(valid, g.ewgt, 0.0), mode="drop")[:-1]
    )
    overflow = (g.indptr[1:] - g.indptr[:-1]) > deg
    return adj.reshape(N, deg), adw.reshape(N, deg), overflow


@functools.partial(jax.jit, static_argnames=("num_blocks",))
def quotient_graph_arrays(g: Graph, part: jax.Array, num_blocks: int):
    """Dense quotient adjacency [k,k] + block weights [k] (for small k)."""
    k = num_blocks
    mask = edge_mask(g)
    pu = jnp.where(mask, part[g.rows], 0)
    pv = jnp.where(mask, part[g.cols], 0)
    w = jnp.where(mask & (pu != pv), g.ewgt, 0.0)
    flat = pu * k + pv
    adj = jax.ops.segment_sum(w, flat, num_segments=k * k).reshape(k, k) / 1.0
    bw = block_weights(g, part, k)
    return adj, bw
