"""Hardware hierarchy: H = a_1 : ... : a_l, D = d_1 : ... : d_l.

Implements the mixed-radix *bit-label* PE-distance trick (O(1) distance
queries, cf. ParHipMap) and the paper's adaptive imbalance (Lemma 5.1).

Convention (matches the paper): ``a_1`` is the innermost level (PEs per
processor) and ``a_l`` the outermost (islands). A PE id is the mixed-radix
number ``pe = digit_l * (a_{l-1}*...*a_1) + ... + digit_2 * a_1 + digit_1``
— i.e. the most significant digit is the island. The hierarchical
multisection partitions top-down: first into ``a_l`` blocks, then ``a_{l-1}``
and so on, so block indices concatenate to exactly this mixed-radix id
(identity mapping).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Hierarchy:
    a: tuple[int, ...]  # a_1 .. a_l  (innermost first)
    d: tuple[float, ...]  # d_1 .. d_l (distance when highest differing level is i)

    def __post_init__(self):
        if len(self.a) != len(self.d):
            raise ValueError("H and D must have equal length")
        if any(x < 1 for x in self.a):
            raise ValueError("hierarchy factors must be >= 1")

    @property
    def l(self) -> int:
        return len(self.a)

    @property
    def k(self) -> int:
        return math.prod(self.a)

    # strides[i] = number of PEs inside one level-i group = a_1*...*a_i
    @property
    def strides(self) -> tuple[int, ...]:
        out = []
        acc = 1
        for ai in self.a:
            acc *= ai
            out.append(acc)
        return tuple(out)

    def digits(self, pe: np.ndarray) -> np.ndarray:
        """Mixed-radix digits of PE ids, innermost first: [*, l]."""
        pe = np.asarray(pe)
        out = np.zeros(pe.shape + (self.l,), np.int64)
        rest = pe.copy()
        for i, ai in enumerate(self.a):
            out[..., i] = rest % ai
            rest //= ai
        return out

    def distance_table(self) -> np.ndarray:
        """[k, k] distance matrix D (for tests/small k)."""
        k = self.k
        pes = np.arange(k)
        dig = self.digits(pes)  # [k, l]
        diff = dig[:, None, :] != dig[None, :, :]  # [k,k,l]
        lvl = np.where(diff.any(-1), self.l - 1 - np.argmax(diff[:, :, ::-1], axis=-1), -1)
        dist = np.zeros((k, k))
        dvec = np.asarray(self.d)
        dist = np.where(lvl >= 0, dvec[np.clip(lvl, 0, self.l - 1)], 0.0)
        return dist

    def __str__(self):
        return "H=" + ":".join(map(str, self.a)) + " D=" + ":".join(f"{x:g}" for x in self.d)


def pe_distance(h: Hierarchy, x: jax.Array, y: jax.Array) -> jax.Array:
    """Vectorized O(1) PE distance (mixed-radix bit-label trick).

    Group sizes below each level: g_0=1, g_1=a_1, g_2=a_1*a_2, ...
    x and y share the level-j group iff ``x // g_j == y // g_j``; the
    distance is ``d_i`` with ``i = min{ j : x//g_j == y//g_j }`` (0 if x==y).
    ``x//g_j != y//g_j`` is monotone decreasing in j, so ``i`` equals the
    count of differing group levels.
    """
    g_below = jnp.asarray((1,) + h.strides[:-1], jnp.int32)  # [l]
    dvec = jnp.asarray(h.d, jnp.float32)                     # [l]
    diff = (x[..., None] // g_below) != (y[..., None] // g_below)  # [*, l]
    lvl = jnp.sum(diff.astype(jnp.int32), axis=-1)  # 0 (equal) .. l
    safe = jnp.clip(lvl - 1, 0, len(h.d) - 1)
    return jnp.where(lvl > 0, dvec[safe], 0.0)


def mapping_cost(h: Hierarchy, rows: jax.Array, cols: jax.Array,
                 ewgt: jax.Array, pe_of: jax.Array, emask: jax.Array) -> jax.Array:
    """J(C, D, Pi) = sum over undirected edges of w * dist(pe_u, pe_v).

    ``rows/cols/ewgt`` are the directed CSR arrays (each undirected edge
    twice) so the sum is halved.
    """
    pu = pe_of[rows]
    pv = pe_of[cols]
    d = pe_distance(h, pu, pv)
    return jnp.sum(jnp.where(emask, ewgt * d, 0.0)) / 2.0


def adaptive_epsilon(eps: float, total_weight: float, sub_weight: float,
                     k: int, k_sub: int, depth: int) -> float:
    """Lemma 5.1: eps' = ((1+eps) * k' c(V) / (k c(V')))^(1/d) - 1.

    ``k_sub`` = number of final PEs below this subgraph (= a_1*...*a_d),
    ``depth``  = d (levels still to partition below/including this one).
    Clamped at >= 0 (a subgraph already over its share gets zero slack).
    """
    if depth <= 0:
        return eps
    ratio = (1.0 + eps) * (k_sub * total_weight) / (k * max(sub_weight, 1e-12))
    return max(ratio ** (1.0 / depth) - 1.0, 0.0)


def adaptive_epsilon_jnp(eps: float, total_weight: jax.Array,
                         sub_weight: jax.Array, k: int, k_sub: int,
                         depth: int) -> jax.Array:
    """Device-side Lemma 5.1 over [B] f32 subgraph weights.

    Same formula as :func:`adaptive_epsilon` but evaluated in float32 on
    device, so the fully device-resident multisection never has to fetch
    subgraph weights to the host. The device path and its host-reference
    twin (LevelPlanner ``resident=False`` under the ``device`` strategy)
    both route through THIS function's jitted program — identical inputs
    give identical eps bits. For integer vertex weights < 2^24 the inputs
    themselves are exact, so the two paths agree bitwise end-to-end; for
    large float weights the f32 sums may differ from the f64 host rule by
    ulps (documented limitation, DESIGN.md §11).
    """
    if depth <= 0:
        return jnp.full(jnp.shape(sub_weight), eps, jnp.float32)
    ratio = ((1.0 + eps) * (k_sub * total_weight)
             / (k * jnp.maximum(sub_weight, 1e-12)))
    out = jnp.maximum(ratio ** jnp.float32(1.0 / depth) - 1.0, 0.0)
    return out.astype(jnp.float32)


def parse_hierarchy(hs: str, ds: str) -> Hierarchy:
    """Parse 'a1:a2:a3' / 'd1:d2:d3' strings (paper notation)."""
    a = tuple(int(x) for x in hs.split(":"))
    d = tuple(float(x) for x in ds.split(":"))
    return Hierarchy(a=a, d=d)


def tpu_v5e_hierarchy(multi_pod: bool = False) -> Hierarchy:
    """The production meshes of this repo as process-mapping hierarchies.

    Single pod : 16 chips/rack x 16 racks      -> H = 16:16,   D = 1:10
    Multi pod  : ... x 2 pods (DCN)            -> H = 16:16:2, D = 1:10:100
    (innermost-first, per paper convention).
    """
    if multi_pod:
        return Hierarchy(a=(16, 16, 2), d=(1.0, 10.0, 100.0))
    return Hierarchy(a=(16, 16), d=(1.0, 10.0))
