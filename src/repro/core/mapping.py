"""Mapping phase: J(C,D,Pi) evaluation, greedy construction and pair-swap
refinement on the quotient (communication-model) graph G_M.

Hierarchical multisection needs only the identity mapping (paper §4); these
routines implement the two-phase baselines:

* ``greedy_mapping``  — Müller-Merbach-style construction: repeatedly place
  the unmapped block with the strongest communication to already-mapped
  blocks onto the free PE with minimal added cost.
* ``swap_refine``     — Brandfass/Schulz-Träff pairwise swaps, restricted to
  communicating pairs (the paper's distance-restricted search, d=1 in G_M
  plus a random sample), vectorized delta-J evaluation.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .graph import Graph, edge_mask
from .hierarchy import Hierarchy, mapping_cost, pe_distance
from ..kernels import ops as kops


def evaluate_J(g: Graph, h: Hierarchy, pe_of: np.ndarray,
               use_pallas: bool | None = None) -> float:
    """Total communication cost J(C, D, Pi) of a vertex->PE assignment.

    Dispatches through ``kernels.ops.mapcost`` — the Pallas edge-tiled
    kernel when live (TPU / forced interpret), the jitted jnp oracle
    otherwise. Padded edge slots carry weight 0, so no mask is needed.
    ``pe_of`` may be a device array (the device-resident pipeline feeds it
    without a host round-trip) or any numpy-convertible sequence.
    """
    pe = jnp.asarray(pe_of, jnp.int32)
    if pe.shape[0] > g.N:
        raise ValueError(
            f"pe_of has {pe.shape[0]} entries but the graph holds only "
            f"{int(g.n)} vertices (padded to N={g.N}); pass one PE id per "
            f"vertex of THIS graph")
    if pe.shape[0] < g.N:
        pe = jnp.concatenate([pe, jnp.zeros(g.N - pe.shape[0], jnp.int32)])
    g_below = jnp.asarray((1,) + h.strides[:-1], jnp.int32)
    dvec = jnp.asarray(h.d, jnp.float32)
    return float(kops.mapcost(g.rows, g.cols, g.ewgt, pe, g_below, dvec,
                              use_pallas=use_pallas))


def quotient_matrix(g: Graph, part: np.ndarray, k: int) -> np.ndarray:
    """Dense symmetric [k,k] communication matrix between blocks."""
    n = int(g.n)
    m = int(g.m)
    rows = np.asarray(g.rows)[:m]
    cols = np.asarray(g.cols)[:m]
    w = np.asarray(g.ewgt)[:m]
    pu = part[rows]
    pv = part[cols]
    mask = pu != pv
    C = np.zeros((k, k))
    np.add.at(C, (pu[mask], pv[mask]), w[mask])
    return (C + C.T) / 2.0  # directed edges stored twice -> symmetrize


def greedy_mapping(C: np.ndarray, h: Hierarchy) -> np.ndarray:
    """Map k blocks onto k PEs greedily (construction heuristic)."""
    k = C.shape[0]
    if k != h.k:
        raise ValueError(f"blocks ({k}) != PEs ({h.k})")
    D = h.distance_table()
    pe_of = np.full(k, -1, np.int64)
    free_pe = np.ones(k, bool)
    mapped = np.zeros(k, bool)

    first = int(np.argmax(C.sum(1)))
    pe_of[first] = 0
    free_pe[0] = False
    mapped[first] = True

    for _ in range(k - 1):
        conn = C[:, mapped].sum(1)
        conn[mapped] = -np.inf
        t = int(np.argmax(conn))
        # added cost of placing t on each free PE
        cost = (C[t, mapped][None, :] * D[:, pe_of[mapped]]).sum(1)
        cost[~free_pe] = np.inf
        p = int(np.argmin(cost))
        pe_of[t] = p
        free_pe[p] = False
        mapped[t] = True
    return pe_of


def map_cost_dense(C: np.ndarray, D: np.ndarray, pe_of: np.ndarray) -> float:
    return float((C * D[np.ix_(pe_of, pe_of)]).sum() / 2.0)


def swap_refine(
    C: np.ndarray,
    h: Hierarchy,
    pe_of: np.ndarray,
    max_passes: int = 10,
    sample: int = 4096,
    seed: int = 0,
) -> np.ndarray:
    """Pairwise-swap local search on the block->PE assignment."""
    k = C.shape[0]
    D = h.distance_table()
    rng = np.random.default_rng(seed)
    pe_of = pe_of.copy()

    iu, iv = np.nonzero(np.triu(C, 1) > 0)
    base_pairs = np.stack([iu, iv], 1) if iu.size else np.zeros((0, 2), np.int64)

    for _ in range(max_passes):
        if k >= 2:
            ru = rng.integers(0, k, sample)
            rv = rng.integers(0, k, sample)
            keep = ru < rv
            pairs = np.concatenate([base_pairs, np.stack([ru[keep], rv[keep]], 1)])
        else:
            pairs = base_pairs
        if pairs.shape[0] == 0:
            break
        a, b = pairs[:, 0], pairs[:, 1]
        pa, pb = pe_of[a], pe_of[b]
        # delta J of swapping assignments of blocks a and b (vectorized).
        # With cost_x_p = sum_j C[x,j] * D[p, pe_of[j]] over OLD assignments
        # and symmetric D, C[x,x] = 0:
        #   J_now(pair) = cost_a_pa + cost_b_pb - C[a,b] * D[pa,pb]
        #   J_new(pair) = cost_a_pb + cost_b_pa + C[a,b] * D[pa,pb]
        #   delta = J_new - J_now
        cost_a_now = (C[a] * D[pa][:, pe_of]).sum(1)
        cost_b_now = (C[b] * D[pb][:, pe_of]).sum(1)
        cost_a_new = (C[a] * D[pb][:, pe_of]).sum(1)
        cost_b_new = (C[b] * D[pa][:, pe_of]).sum(1)
        delta = (cost_a_new + cost_b_new) - (cost_a_now + cost_b_now) \
            + 2.0 * C[a, b] * D[pa, pb]
        order = np.argsort(delta)
        improved = False
        touched = np.zeros(k, bool)
        for idx in order:
            if delta[idx] >= -1e-12:
                break
            x, y = int(a[idx]), int(b[idx])
            if touched[x] or touched[y]:
                continue
            # exact delta check before applying
            old = _pair_cost(C, D, pe_of, x, y)
            pe_of[x], pe_of[y] = pe_of[y], pe_of[x]
            new = _pair_cost(C, D, pe_of, x, y)
            if new >= old - 1e-12:
                pe_of[x], pe_of[y] = pe_of[y], pe_of[x]
                continue
            touched[x] = touched[y] = True
            improved = True
        if not improved:
            break
    return pe_of


def _pair_cost(C: np.ndarray, D: np.ndarray, pe_of: np.ndarray, x: int, y: int) -> float:
    cx = (C[x] * D[pe_of[x], pe_of]).sum()
    cy = (C[y] * D[pe_of[y], pe_of]).sum()
    return float(cx + cy - C[x, y] * D[pe_of[x], pe_of[y]])
