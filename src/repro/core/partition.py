"""Multilevel k-way partitioner (the KaFFPa/Mt-KaHyPar substrate, in JAX).

V-cycle: HEM-coarsen until the graph is small, greedy-grow an initial
k-way partition, project back up with LP refinement + rebalance per level.
Presets FAST/ECO/STRONG trade rounds/restarts for quality; restarts are
vectorized with `vmap` over salts (the TPU-native analogue of KaFFPa's
repeated runs) and the best balanced partition wins.

The whole pipeline is static-shape: one compiled program per
(N, M, k, levels, preset), reused across all subgraphs of a hierarchy level
and `vmap`-able for the LAYER/BUCKET scheduling strategies.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import threading
from typing import Callable

import jax
import jax.numpy as jnp

from .coarsen import coarsen_once
from .graph import Graph, block_weights, default_ell_deg, edge_cut
from .initial import initial_partition
from .refine import lp_refine, rebalance


@dataclasses.dataclass(frozen=True)
class Preset:
    name: str
    refine_rounds: int      # LP rounds per uncoarsening level
    coarsest_polish: int    # LP rounds on the coarsest graph
    restarts: int           # vmapped seeded restarts
    vcycles: int            # extra refine-only cycles at the finest level

    @staticmethod
    def get(name: str) -> "Preset":
        return _PRESETS[name.lower()]


_PRESETS = {
    "fast": Preset("fast", refine_rounds=2, coarsest_polish=4, restarts=1, vcycles=0),
    "eco": Preset("eco", refine_rounds=4, coarsest_polish=8, restarts=2, vcycles=1),
    "strong": Preset("strong", refine_rounds=8, coarsest_polish=12, restarts=4, vcycles=2),
}


def num_levels(n: int, k: int, coarse_factor: int = 24) -> int:
    """Static coarsening depth: HEM shrinks ~1.6x/level; stop near 24*k."""
    target = max(coarse_factor * k, 64)
    if n <= target:
        return 0
    return max(1, math.ceil(math.log(n / target) / math.log(1.6)))


def _partition_single(
    g: Graph, k: int, eps: jax.Array, levels: int, preset: Preset, salt: jax.Array,
    backend: str = "auto", ell_deg: int | None = None,
) -> jax.Array:
    """One seeded multilevel run. Python loop over levels unrolls at trace
    time (static count); all shapes stay (N, M)."""
    total = g.total_weight()
    Lmax = (1.0 + eps) * total / k

    graphs = [g]
    maps = []
    cur = g
    for lvl in range(levels):
        cur, newid = coarsen_once(cur, salt=(lvl + 1) * 131 + 7)
        graphs.append(cur)
        maps.append(newid)

    part = initial_partition(
        graphs[-1], k, Lmax, salt=salt, polish_rounds=preset.coarsest_polish
    )

    for lvl in range(levels - 1, -1, -1):
        part = part[maps[lvl]]  # project to finer level
        part = lp_refine(
            graphs[lvl], part, k, Lmax, rounds=preset.refine_rounds,
            salt=salt + 1000 + lvl, backend=backend, ell_deg=ell_deg,
        )
        part = rebalance(graphs[lvl], part, k, Lmax, rounds=4,
                         salt=salt + 2000 + lvl, backend=backend, ell_deg=ell_deg)

    for cyc in range(preset.vcycles):
        part = lp_refine(g, part, k, Lmax, rounds=preset.refine_rounds,
                         salt=salt + 3000 + cyc, backend=backend, ell_deg=ell_deg)
        part = rebalance(g, part, k, Lmax, rounds=4, salt=salt + 4000 + cyc,
                         backend=backend, ell_deg=ell_deg)
    return part


@functools.partial(
    jax.jit, static_argnames=("k", "levels", "preset_name", "backend", "ell_deg")
)
def partition(
    g: Graph,
    k: int,
    eps: jax.Array,
    levels: int,
    preset_name: str = "eco",
    salt: int | jax.Array = 0,
    backend: str = "auto",
    ell_deg: int | None = None,
) -> jax.Array:
    """Balanced k-way partition of ``g`` minimizing edge-cut.

    Restarts run vectorized over salts; the winner is the best *balanced*
    partition by edge-cut (unbalanced runs are heavily penalized).
    ``ell_deg`` (static) pins the ELL degree cap for the kernel-backed
    refinement; pass one computed from the REAL vertex/edge counts (pow2
    padding skews the in-jit default by up to 2x; see core/refine.py).
    """
    preset = Preset.get(preset_name)
    salt = jnp.asarray(salt, jnp.int32)
    if k == 1:
        return jnp.zeros((g.N,), jnp.int32)

    salts = salt * 131 + jnp.arange(preset.restarts, dtype=jnp.int32) * 7919

    def run(s):
        p = _partition_single(g, k, eps, levels, preset, s, backend, ell_deg)
        cut = edge_cut(g, p)
        Lmax = (1.0 + eps) * g.total_weight() / k
        over = jnp.maximum(block_weights(g, p, k) - Lmax, 0.0).sum()
        return p, cut + 1e6 * over

    parts, scores = jax.vmap(run)(salts)
    best = jnp.argmin(scores)
    return parts[best]


_BATCHED_CACHE: dict[tuple, Callable] = {}
_BATCHED_LOCK = threading.Lock()


def batched_partition(k: int, levels: int, preset: str, backend: str,
                      ell_deg: int | None) -> Callable:
    """Memoized jitted vmapped partition callable ``(gs, eps, salts) ->
    [B, N] parts`` — the dispatch unit of every bucket/layer/device-level
    partition call (one executable per static key, shared process-wide
    across hierarchy levels, strategies and requests).

    Lives here (not in multisection) so every consumer of batched
    partitions — the level planner, the device-resident loop, external
    tools — shares one memo. The memoized jitted wrapper hits jit's C++
    fast path on repeat calls with the same shapes (an AOT
    ``.lower().compile()`` executable measured SLOWER: its Python
    ``Compiled.__call__`` costs more than jit dispatch).
    """
    key = (k, levels, preset, backend, ell_deg)
    with _BATCHED_LOCK:
        fn = _BATCHED_CACHE.get(key)
        if fn is None:
            fn = jax.jit(lambda gs, ee, ss: jax.vmap(
                lambda g1, e1, s1: partition(g1, k, e1, levels, preset, s1,
                                             backend, ell_deg)
            )(gs, ee, ss))
            _BATCHED_CACHE[key] = fn
    return fn


def clear_batched_partition_cache() -> None:
    with _BATCHED_LOCK:
        _BATCHED_CACHE.clear()


def partition_host(g: Graph, k: int, eps: float, preset: str = "eco", salt: int = 0,
                   backend: str = "auto") -> jax.Array:
    """Convenience wrapper choosing level count + ELL degree cap from the
    REAL sizes (not the padded shapes)."""
    from .refine import resolve_backend
    lv = num_levels(int(g.n), k)
    deg = (default_ell_deg(int(g.n), int(g.m))
           if resolve_backend(backend) == "ell" else None)
    return partition(g, k, jnp.float32(eps), lv, preset, salt, backend, deg)
