"""Multilevel k-way partitioner (the KaFFPa/Mt-KaHyPar substrate, in JAX).

V-cycle: HEM-coarsen until the graph is small, greedy-grow an initial
k-way partition, project back up with LP refinement + rebalance per level.
Presets FAST/ECO/STRONG trade rounds/restarts for quality; restarts are
vectorized with `vmap` over salts (the TPU-native analogue of KaFFPa's
repeated runs) and the best balanced partition wins.

The whole pipeline is static-shape: one compiled program per
(N, M, k, levels, preset), reused across all subgraphs of a hierarchy level
and `vmap`-able for the LAYER/BUCKET scheduling strategies.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import threading
from typing import Callable

import jax
import jax.numpy as jnp

from .coarsen import coarsen_once
from .graph import Graph, block_weights, default_ell_deg, edge_cut
from .initial import initial_partition
from .refine import lp_refine, rebalance


@dataclasses.dataclass(frozen=True)
class Preset:
    name: str
    refine_rounds: int      # LP rounds per uncoarsening level
    coarsest_polish: int    # LP rounds on the coarsest graph
    restarts: int           # vmapped seeded restarts
    vcycles: int            # extra refine-only cycles at the finest level

    @staticmethod
    def get(name: str) -> "Preset":
        return _PRESETS[name.lower()]


_PRESETS = {
    "fast": Preset("fast", refine_rounds=2, coarsest_polish=4, restarts=1, vcycles=0),
    "eco": Preset("eco", refine_rounds=4, coarsest_polish=8, restarts=2, vcycles=1),
    "strong": Preset("strong", refine_rounds=8, coarsest_polish=12, restarts=4, vcycles=2),
}


def num_levels(n: int, k: int, coarse_factor: int = 24,
               max_degree: int | None = None) -> int:
    """Static coarsening depth: HEM shrinks ~1.6x/level; stop near 24*k.

    ``max_degree`` (when the caller has a host graph to measure it on)
    guards against matching stalls: a degree-``d`` hub serializes its
    whole neighbourhood behind one matching edge, so at most
    ``n - max_degree`` pairs can form per level. On star-like graphs the
    implied shrink collapses toward 1x — deeper levels would barely
    shrink, so we STOP at one level; on merely hub-heavy graphs the
    shrink lands between 1x and 1.6x and the depth is EXTENDED (capped)
    so the coarsest graph still approaches the target size.
    """
    target = max(coarse_factor * k, 64)
    if n <= target:
        return 0
    base = max(1, math.ceil(math.log(n / target) / math.log(1.6)))
    if max_degree is None:
        return base
    pairs = max(1, min(n // 2, n - int(max_degree)))
    shrink = n / max(1.0, n - pairs)
    if shrink < 1.15:
        return 1  # stalled: coarsening cannot help, don't pay for depth
    shrink = min(1.6, shrink)
    lv = math.ceil(math.log(n / target) / math.log(shrink))
    return max(1, min(lv, 2 * base + 4))


def _partition_single(
    g: Graph, k: int, eps: jax.Array, levels: int, preset: Preset, salt: jax.Array,
    backend: str = "auto", ell_deg: int | None = None, coarsen: str = "ell",
) -> jax.Array:
    """One seeded multilevel run; all shapes stay (N, M).

    ``coarsen="ell"`` (default) is the fused v-cycle: coarsening runs
    through the ELL kernels and both the downward (coarsen) and upward
    (project + refine) level loops are ``lax.scan``s over stacked
    same-shape graphs — ONE compiled loop body per (N, M, k, preset)
    regardless of depth, instead of ``levels`` unrolled copies. That
    removes the per-level retrace/compile cost that dominated the cold
    path at 10^5+ vertices. ``coarsen="segment"`` keeps the seed's
    unrolled segment-reduction path (the PR 8 baseline, and the bench
    comparison mode).
    """
    total = g.total_weight()
    Lmax = (1.0 + eps) * total / k

    if levels == 0:
        part = initial_partition(
            g, k, Lmax, salt=salt, polish_rounds=preset.coarsest_polish,
            backend=backend, ell_deg=ell_deg)
    elif coarsen == "segment":
        graphs = [g]
        maps = []
        cur = g
        for lvl in range(levels):
            cur, newid = coarsen_once(cur, salt=(lvl + 1) * 131 + 7)
            graphs.append(cur)
            maps.append(newid)
        part = initial_partition(
            graphs[-1], k, Lmax, salt=salt,
            polish_rounds=preset.coarsest_polish,
            backend=backend, ell_deg=ell_deg,
        )
        for lvl in range(levels - 1, -1, -1):
            part = part[maps[lvl]]  # project to finer level
            part = lp_refine(
                graphs[lvl], part, k, Lmax, rounds=preset.refine_rounds,
                salt=salt + 1000 + lvl, backend=backend, ell_deg=ell_deg,
            )
            part = rebalance(graphs[lvl], part, k, Lmax, rounds=4,
                             salt=salt + 2000 + lvl, backend=backend,
                             ell_deg=ell_deg)
    else:
        # static DEG cap for the coarsening kernels; reuse the refinement
        # cap when the ELL refinement backend pinned one
        deg_c = ell_deg if ell_deg is not None else default_ell_deg(g.N, g.M)
        csalts = (jnp.arange(levels, dtype=jnp.int32) + 1) * 131 + 7

        def down(cur, sl):
            gc, newid = coarsen_once(cur, salt=sl, ell_deg=deg_c)
            return gc, (cur, newid)   # emit the FINE graph of this level

        coarsest, (fines, maps) = jax.lax.scan(down, g, csalts)
        part = initial_partition(
            coarsest, k, Lmax, salt=salt,
            polish_rounds=preset.coarsest_polish,
            backend=backend, ell_deg=ell_deg,
        )
        lvls = jnp.arange(levels, dtype=jnp.int32)

        def up(part, x):
            gf, mp, lvl = x
            part = part[mp]  # project to finer level
            part = lp_refine(gf, part, k, Lmax, rounds=preset.refine_rounds,
                             salt=salt + 1000 + lvl, backend=backend,
                             ell_deg=ell_deg)
            part = rebalance(gf, part, k, Lmax, rounds=4,
                             salt=salt + 2000 + lvl, backend=backend,
                             ell_deg=ell_deg)
            return part, None

        part, _ = jax.lax.scan(up, part, (fines, maps, lvls), reverse=True)

    for cyc in range(preset.vcycles):
        part = lp_refine(g, part, k, Lmax, rounds=preset.refine_rounds,
                         salt=salt + 3000 + cyc, backend=backend, ell_deg=ell_deg)
        part = rebalance(g, part, k, Lmax, rounds=4, salt=salt + 4000 + cyc,
                         backend=backend, ell_deg=ell_deg)
    return part


@functools.partial(
    jax.jit,
    static_argnames=("k", "levels", "preset_name", "backend", "ell_deg", "coarsen"),
)
def partition(
    g: Graph,
    k: int,
    eps: jax.Array,
    levels: int,
    preset_name: str = "eco",
    salt: int | jax.Array = 0,
    backend: str = "auto",
    ell_deg: int | None = None,
    coarsen: str = "ell",
) -> jax.Array:
    """Balanced k-way partition of ``g`` minimizing edge-cut.

    Restarts run vectorized over salts; the winner is the best *balanced*
    partition by edge-cut (unbalanced runs are heavily penalized).
    ``ell_deg`` (static) pins the ELL degree cap for the kernel-backed
    refinement; pass one computed from the REAL vertex/edge counts (pow2
    padding skews the in-jit default by up to 2x; see core/refine.py).
    ``coarsen`` selects the coarsening implementation: ``"ell"`` (default)
    is the fused kernel v-cycle, ``"segment"`` the seed's unrolled
    segment-reduction path (see ``_partition_single``).
    """
    preset = Preset.get(preset_name)
    salt = jnp.asarray(salt, jnp.int32)
    if k == 1:
        return jnp.zeros((g.N,), jnp.int32)

    salts = salt * 131 + jnp.arange(preset.restarts, dtype=jnp.int32) * 7919

    def run(s):
        p = _partition_single(g, k, eps, levels, preset, s, backend, ell_deg,
                              coarsen)
        cut = edge_cut(g, p)
        Lmax = (1.0 + eps) * g.total_weight() / k
        over = jnp.maximum(block_weights(g, p, k) - Lmax, 0.0).sum()
        return p, cut + 1e6 * over

    parts, scores = jax.vmap(run)(salts)
    best = jnp.argmin(scores)
    return parts[best]


_BATCHED_CACHE: dict[tuple, Callable] = {}
_BATCHED_LOCK = threading.Lock()


def batched_partition(k: int, levels: int, preset: str, backend: str,
                      ell_deg: int | None, coarsen: str = "ell") -> Callable:
    """Memoized jitted vmapped partition callable ``(gs, eps, salts) ->
    [B, N] parts`` — the dispatch unit of every bucket/layer/device-level
    partition call (one executable per static key, shared process-wide
    across hierarchy levels, strategies and requests).

    Lives here (not in multisection) so every consumer of batched
    partitions — the level planner, the device-resident loop, external
    tools — shares one memo. The memoized jitted wrapper hits jit's C++
    fast path on repeat calls with the same shapes (an AOT
    ``.lower().compile()`` executable measured SLOWER: its Python
    ``Compiled.__call__`` costs more than jit dispatch).

    The key includes the process-wide kernel backend (REPRO_KERNEL_BACKEND):
    coarsening + refinement dispatch through kernels/ops at TRACE time, so
    a memoized callable is only valid for the backend it traced under
    (the backend-invariance tests flip the env between calls).
    """
    from ..kernels import ops as kops
    key = (k, levels, preset, backend, ell_deg, coarsen, kops.kernel_backend())
    with _BATCHED_LOCK:
        fn = _BATCHED_CACHE.get(key)
        if fn is None:
            fn = jax.jit(lambda gs, ee, ss: jax.vmap(
                lambda g1, e1, s1: partition(g1, k, e1, levels, preset, s1,
                                             backend, ell_deg, coarsen)
            )(gs, ee, ss))
            _BATCHED_CACHE[key] = fn
    return fn


def clear_batched_partition_cache() -> None:
    with _BATCHED_LOCK:
        _BATCHED_CACHE.clear()


def partition_host(g: Graph, k: int, eps: float, preset: str = "eco", salt: int = 0,
                   backend: str = "auto", coarsen: str = "ell") -> jax.Array:
    """Convenience wrapper choosing level count + ELL degree cap from the
    REAL sizes (not the padded shapes); with a host graph in hand it also
    measures the max degree so ``num_levels`` can detect matching stalls
    (star-like graphs) and size the cascade accordingly."""
    import numpy as np
    from .refine import resolve_backend
    n = int(g.n)
    ind = np.asarray(g.indptr)
    maxdeg = int((ind[1:n + 1] - ind[:n]).max()) if n > 0 else 0
    lv = num_levels(n, k, max_degree=maxdeg)
    deg = (default_ell_deg(int(g.n), int(g.m))
           if resolve_backend(backend) == "ell" else None)
    return partition(g, k, jnp.float32(eps), lv, preset, salt, backend, deg,
                     coarsen)
