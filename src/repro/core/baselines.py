"""Baseline GPMP solvers the paper compares against (§3, §6.4).

* ``kaffpa_map_style``     — two-phase: flat k-way partition of G_C via
  recursive bisection (expressed as multisection over H=(2,...,2)), then
  hierarchical multisection of the quotient graph G_M + greedy construction
  + pair-swap refinement. (KAFFPA-MAP [38])
* ``global_multisection``  — hierarchical multisection WITHOUT the adaptive
  imbalance (eps' = eps at every level), plus swap refinement — the paper's
  explanation for why SharedMap beats GM on quality/balance. (GM [42])
* ``random_mapping`` / ``identity_mapping`` — sanity floors.
"""
from __future__ import annotations

import math

import numpy as np

from .graph import Graph
from .hierarchy import Hierarchy
from .mapping import evaluate_J, greedy_mapping, quotient_matrix, swap_refine
from .multisection import MultisectionResult, hierarchical_multisection


def identity_mapping(g: Graph, h: Hierarchy, seed: int = 0) -> np.ndarray:
    """Blocks of contiguous vertex ids -> PEs (what a naive launcher does)."""
    n = int(g.n)
    k = h.k
    return (np.arange(n, dtype=np.int64) * k) // max(n, 1)


def random_mapping(g: Graph, h: Hierarchy, seed: int = 0) -> np.ndarray:
    n = int(g.n)
    k = h.k
    rng = np.random.default_rng(seed)
    pe = (np.arange(n, dtype=np.int64) * k) // max(n, 1)
    return rng.permutation(k)[pe]


def greedy_baseline(g: Graph, h: Hierarchy, seed: int = 0) -> np.ndarray:
    """Cheapest non-trivial mapping: contiguous-block partition + greedy
    quotient-graph placement (no multisection, no refinement, O(m + k^2)).

    This is the FLOOR of the mapping service's graceful-degradation ladder
    (serve/mapper): under hard overload or repeated kernel-path failures it
    still beats `identity_mapping` (the greedy pass packs heavily
    communicating blocks into near PEs) while costing microseconds."""
    part = identity_mapping(g, h, seed)
    C = quotient_matrix(g, part, h.k)
    perm = greedy_mapping(C, h)
    return perm[part]


def global_multisection(
    g: Graph, h: Hierarchy, eps: float = 0.03, preset: str = "eco",
    strategy: str = "bucket", seed: int = 0, backend: str = "auto",
) -> MultisectionResult:
    """GM [42]: multisection with FIXED eps per level + swap refinement."""
    res = hierarchical_multisection(
        g, h, eps=eps, preset=preset, strategy=strategy, seed=seed,
        adaptive=False, backend=backend,
    )
    res.stats["J_before_refine"] = evaluate_J(g, h, res.pe_of)
    C = quotient_matrix(g, res.pe_of, h.k)
    pe_perm = swap_refine(C, h, np.arange(h.k, dtype=np.int64), seed=seed)
    res.pe_of = pe_perm[res.pe_of]
    res.stats["refined"] = True
    res.stats["J_after_refine"] = evaluate_J(g, h, res.pe_of)
    return res


def kaffpa_map_style(
    g: Graph, h: Hierarchy, eps: float = 0.03, preset: str = "eco",
    strategy: str = "bucket", seed: int = 0, backend: str = "auto",
) -> MultisectionResult:
    """KAFFPA-MAP [38]: flat k-way first, then map the quotient graph."""
    k = h.k
    lg = math.log2(k)
    if lg != int(lg):
        raise ValueError("kaffpa_map_style requires power-of-two k")
    # phase 1: recursive bisection == multisection over H=(2,)*log2(k)
    rb = Hierarchy(a=(2,) * int(lg), d=(1.0,) * int(lg))
    res = hierarchical_multisection(
        g, rb, eps=eps, preset=preset, strategy=strategy, seed=seed,
        adaptive=True, backend=backend,
    )
    part = res.pe_of  # k-way partition (block ids)
    # phase 2: hierarchical multisection of G_M (k vertices) -> greedy -> swap
    C = quotient_matrix(g, part, k)
    pe_perm = greedy_mapping(C, h)
    pe_perm = swap_refine(C, h, pe_perm, seed=seed)
    res.pe_of = pe_perm[part]
    res.stats["refined"] = True
    res.stats["J_after_refine"] = evaluate_J(g, h, res.pe_of)
    return res
