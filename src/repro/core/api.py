"""SharedMap public API.

>>> from repro.core.api import shared_map, SharedMapConfig
>>> res = shared_map(graph, hierarchy)          # the paper's algorithm
>>> res.pe_of                                    # vertex -> PE mapping
>>> res.J                                        # communication cost
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .graph import Graph
from .hierarchy import Hierarchy
from .mapping import evaluate_J
from .multisection import hierarchical_multisection
from .taskgraph import TaskGraph


@dataclasses.dataclass(frozen=True)
class SharedMapConfig:
    eps: float = 0.03
    preset: str = "eco"          # fast | eco | strong
    strategy: str = "bucket"     # naive | layer | bucket | queue | device
    # ("device" = the fully device-resident level loop: fixed root-shape
    #  schedule, on-device split/eps/pe accumulation, exactly ONE
    #  device->host fetch per request; see core/multisection.py.)
    seed: int = 0
    adaptive: bool = True        # Lemma 5.1 adaptive imbalance
    backend: str = "auto"        # refinement kernels: auto | ell | xla
    # ("ell" = Pallas lp_gain kernels over the padded [N, DEG] adjacency;
    #  "auto" picks it whenever kernels.ops.kernel_backend() is live.)
    coarsen_telemetry: bool = False  # fill stats["coarsen"] with the root
    # graph's per-level cascade sizes (one extra device pass; the mapping
    # itself is unchanged). See multisection.hierarchical_multisection.
    refine_mapping: bool = False  # optional block<->PE swap pass. The paper's
    # SharedMap deliberately has none (§6.4) — with a KaFFPa-strength
    # partitioner it is unnecessary. Our JAX substrate partitioner is weaker,
    # so this evens the comparison against GM (which does refine); see
    # DESIGN.md §2.3.


@dataclasses.dataclass
class SharedMapResult:
    pe_of: np.ndarray
    J: float
    stats: dict


# An installed serve.mapper.MappingService (None = direct execution). The
# service registers itself here so `shared_map` callers transparently gain
# cross-request batching and the result cache; the hook lives on this side
# to keep core free of any serve import (serve.mapper imports core).
_SERVICE = None


def install_service(service) -> object | None:
    """Route ``shared_map`` through ``service`` (None = direct path).
    Returns the previously installed service."""
    global _SERVICE
    prev = _SERVICE
    _SERVICE = service
    return prev


def current_service():
    return _SERVICE


def shared_map(g: Graph | TaskGraph, h: Hierarchy,
               config: SharedMapConfig | None = None) -> SharedMapResult:
    """Solve GPMP for communication graph ``g`` on hierarchy ``h``.

    ``g`` is either the canonical CSR :class:`Graph` or a workload-layer
    :class:`TaskGraph` (``core/taskgraph.py``); a TaskGraph is lowered via
    its cached ``to_graph()``, so both spellings produce bit-identical
    results, and the service keys its caches on ``TaskGraph.fingerprint()``.

    When a mapping service is installed (serve.mapper), the request is
    served through it — coalesced with concurrent requests and answered
    from the result cache when possible; results are bit-identical to the
    direct path either way.
    """
    cfg = config or SharedMapConfig()
    if _SERVICE is not None:
        return _SERVICE.map(g, h, cfg)
    return shared_map_direct(g, h, cfg)


def shared_map_direct(g: Graph | TaskGraph, h: Hierarchy, cfg: SharedMapConfig,
                      checkpoint=None, resident=None) -> SharedMapResult:
    """The in-process path (no service indirection); also the fallback the
    service itself uses for the non-plannable strategies (naive/queue).

    ``checkpoint`` (optional zero-arg callable) is invoked between
    multisection levels; raising inside it aborts the run — the service
    uses it to enforce deadlines and shutdown on fallback requests.

    ``resident`` overrides the planner strategies' device residency
    (None = strategy default): the service's shadow verifier passes
    ``resident=False`` to run a request on the bitwise host-ref twin of
    the device pipeline, and its worker processes forward the session's
    device-quarantine decision the same way."""
    if isinstance(g, TaskGraph):
        g = g.to_graph()
    res = hierarchical_multisection(
        g, h, eps=cfg.eps, preset=cfg.preset, strategy=cfg.strategy,
        seed=cfg.seed, adaptive=cfg.adaptive, backend=cfg.backend,
        checkpoint=checkpoint, resident=resident,
        coarsen_telemetry=cfg.coarsen_telemetry,
    )
    res.pe_of = finalize_mapping(g, h, cfg, res.pe_of, res.stats)
    return SharedMapResult(pe_of=res.pe_of, J=evaluate_J(g, h, res.pe_of), stats=res.stats)


def finalize_mapping(g: Graph, h: Hierarchy, cfg: SharedMapConfig,
                     pe_of: np.ndarray, stats: dict) -> np.ndarray:
    """The shared post-multisection step: optional block<->PE swap pass.
    Split out so the service's planner path applies EXACTLY the same
    finalization as the direct path (bit-identity)."""
    if cfg.refine_mapping:
        from .mapping import quotient_matrix, swap_refine
        C = quotient_matrix(g, pe_of, h.k)
        perm = swap_refine(C, h, np.arange(h.k, dtype=np.int32), seed=cfg.seed)
        pe_of = perm[pe_of].astype(np.int32, copy=False)
        stats["refined"] = True
    return pe_of
