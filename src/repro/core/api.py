"""SharedMap public API.

>>> from repro.core.api import shared_map, SharedMapConfig
>>> res = shared_map(graph, hierarchy)          # the paper's algorithm
>>> res.pe_of                                    # vertex -> PE mapping
>>> res.J                                        # communication cost
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .graph import Graph
from .hierarchy import Hierarchy
from .mapping import evaluate_J
from .multisection import hierarchical_multisection


@dataclasses.dataclass(frozen=True)
class SharedMapConfig:
    eps: float = 0.03
    preset: str = "eco"          # fast | eco | strong
    strategy: str = "bucket"     # naive | layer | bucket | queue
    seed: int = 0
    adaptive: bool = True        # Lemma 5.1 adaptive imbalance
    backend: str = "auto"        # refinement kernels: auto | ell | xla
    # ("ell" = Pallas lp_gain kernels over the padded [N, DEG] adjacency;
    #  "auto" picks it whenever kernels.ops.kernel_backend() is live.)
    refine_mapping: bool = False  # optional block<->PE swap pass. The paper's
    # SharedMap deliberately has none (§6.4) — with a KaFFPa-strength
    # partitioner it is unnecessary. Our JAX substrate partitioner is weaker,
    # so this evens the comparison against GM (which does refine); see
    # DESIGN.md §2.3.


@dataclasses.dataclass
class SharedMapResult:
    pe_of: np.ndarray
    J: float
    stats: dict


def shared_map(g: Graph, h: Hierarchy, config: SharedMapConfig | None = None) -> SharedMapResult:
    """Solve GPMP for communication graph ``g`` on hierarchy ``h``."""
    cfg = config or SharedMapConfig()
    res = hierarchical_multisection(
        g, h, eps=cfg.eps, preset=cfg.preset, strategy=cfg.strategy,
        seed=cfg.seed, adaptive=cfg.adaptive, backend=cfg.backend,
    )
    if cfg.refine_mapping:
        from .mapping import quotient_matrix, swap_refine
        C = quotient_matrix(g, res.pe_of, h.k)
        perm = swap_refine(C, h, np.arange(h.k, dtype=np.int64), seed=cfg.seed)
        res.pe_of = perm[res.pe_of]
        res.stats["refined"] = True
    return SharedMapResult(pe_of=res.pe_of, J=evaluate_J(g, h, res.pe_of), stats=res.stats)
