"""SharedMap core: the paper's contribution (hierarchical multisection
process mapping) and its substrate (multilevel graph partitioner), in JAX."""
from .api import SharedMapConfig, SharedMapResult, shared_map  # noqa: F401
from .graph import Graph, from_edges  # noqa: F401
from .hierarchy import Hierarchy, adaptive_epsilon, pe_distance  # noqa: F401
