"""xlstm-125m [ssm] — alternating sLSTM + mLSTM blocks, d_ff=0 (cell-only
blocks). [arXiv:2405.04517; unverified]"""
from repro.models.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm",
        num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304, slstm_every=2, rope_theta=0.0,
        tie_embeddings=False,
    )


def make_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="ssm",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=256, slstm_every=2, rope_theta=0.0,
    )
