"""qwen1.5-110b [dense] — QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.models.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b", family="dense",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=49152, vocab_size=152064, qkv_bias=True,
    )


def make_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=192, vocab_size=256, qkv_bias=True,
    )
