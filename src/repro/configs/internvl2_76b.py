"""internvl2-76b [vlm] — InternViT (STUB patch embeddings) + InternLM2-style
LM backbone, GQA kv=8. [arXiv:2404.16821; unverified]"""
from repro.models.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b", family="vlm",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=28672, vocab_size=128256,
        frontend="vision_stub", num_patches=256,
    )


def make_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256,
        frontend="vision_stub", num_patches=8,
    )
