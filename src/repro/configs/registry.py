"""Architecture registry: ``get_config(arch_id)`` + the assigned shape set."""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCHS = (
    "mixtral-8x22b",
    "moonshot-v1-16b-a3b",
    "whisper-tiny",
    "qwen2-72b",
    "qwen1.5-110b",
    "llama3.2-3b",
    "command-r-plus-104b",
    "internvl2-76b",
    "xlstm-125m",
    "jamba-v0.1-52b",
)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


SHAPES = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


def _mod_name(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_mod_name(arch)}")
    return mod.make_config()


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_mod_name(arch)}")
    return mod.make_smoke_config()


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> tuple[bool, str]:
    """The assignment's skip rules (documented in DESIGN.md §6)."""
    if cell.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k skipped: pure full-attention arch (assignment rule)"
    return True, ""


def all_cells():
    for arch in ARCHS:
        cfg = get_config(arch)
        for cell in SHAPES:
            ok, why = cell_applicable(cfg, cell)
            yield arch, cfg, cell, ok, why
