"""command-r-plus-104b [dense] — GQA kv=8, no-bias.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.models.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b", family="dense",
        num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8,
        d_ff=33792, vocab_size=256000,
    )


def make_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="command-r-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=176, vocab_size=256,
    )
