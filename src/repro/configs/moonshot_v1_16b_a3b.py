"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.models.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe",
        num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, vocab_size=163840,
        num_experts=64, top_k=6,
    )


def make_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=96, vocab_size=256,
        capacity_factor=8.0, num_experts=8, top_k=2,
    )
