"""llama3.2-3b [dense] — small llama3, GQA kv=8. [hf:meta-llama/Llama-3.2-1B; unverified]"""
from repro.models.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b", family="dense",
        num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8,
        d_ff=8192, vocab_size=128256, rope_theta=500000.0,
    )


def make_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-smoke", family="dense",
        num_layers=2, d_model=48, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, rope_theta=500000.0,
    )
