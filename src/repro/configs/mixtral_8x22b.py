"""mixtral-8x22b [moe] — 8 experts top-2, GQA kv=8, SWA. [arXiv:2401.04088; hf]"""
from repro.models.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=16384, vocab_size=32768,
        num_experts=8, top_k=2, sliding_window=4096,
    )


def make_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256,
        capacity_factor=4.0, num_experts=4, top_k=2, sliding_window=16,
    )
