"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7 interleave, MoE 16e top-2
every other layer. [arXiv:2403.19887; hf]"""
from repro.models.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=65536,
        num_experts=16, top_k=2, attn_period=8, moe_period=2,
        mamba_d_state=16, mamba_d_conv=4, mamba_expand=2, rope_theta=0.0,
    )


def make_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke", family="hybrid",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=256,
        capacity_factor=4.0, num_experts=4, top_k=2, attn_period=4, moe_period=2,
        mamba_d_state=8, mamba_d_conv=4, mamba_expand=2, rope_theta=0.0,
    )
