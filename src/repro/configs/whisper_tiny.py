"""whisper-tiny [audio] — enc-dec, conv frontend STUB (precomputed frame
embeddings via input_specs). [arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny", family="audio",
        num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
        d_ff=1536, vocab_size=51865,
        encoder_layers=4, norm="layernorm", act="gelu", rope_theta=0.0,
        frontend="audio_stub", max_target_len=448, tie_embeddings=True,
    )


def make_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        encoder_layers=2, norm="layernorm", act="gelu", rope_theta=0.0,
        frontend="audio_stub", max_target_len=32, tie_embeddings=True,
    )
