"""Family dispatch: one uniform API over the whole zoo.

    init_fn(cfg, key, V)          -> params pytree
    loss_fn(cfg, params, batch)   -> scalar (train objective)
    prefill_fn / decode_fn        -> serving paths
    input_specs(cfg, shape)       -> ShapeDtypeStructs for the dry-run
    scan_trip_hints(cfg, shape)   -> while-loop trip counts for HLO analysis
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import transformer as tfm
from . import whisper as wsp
from .config import ModelConfig
from .layers import CDTYPE
from .sharding import ShardCtx


def init_fn(cfg: ModelConfig, key, V: int = 1):
    if cfg.is_encoder_decoder:
        return wsp.init_params(cfg, key, V=V)
    return tfm.init_params(cfg, key, V=V)


def loss_fn(cfg: ModelConfig, params, batch, ctx: ShardCtx | None = None):
    if cfg.is_encoder_decoder:
        return wsp.seq2seq_loss(cfg, params, batch, ctx)
    return tfm.lm_loss(cfg, params, batch, ctx)


def prefill_fn(cfg: ModelConfig, params, batch, ctx: ShardCtx | None = None):
    if cfg.is_encoder_decoder:
        return wsp.prefill_memory(cfg, params, batch["frames"], ctx)
    return tfm.prefill(cfg, params, batch, ctx)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, V: int = 1):
    if cfg.is_encoder_decoder:
        cache = wsp.init_cache(cfg, batch, min(max_len, cfg.max_target_len), V=V)
        # cross-attn memory of `max_len` encoder frames
        cache["mem_kv"] = (
            jnp.zeros((cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim), CDTYPE),
            jnp.zeros((cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim), CDTYPE),
        )
        return cache
    return tfm.init_cache(cfg, batch, max_len, V=V)


def decode_fn(cfg: ModelConfig, params, tokens, cache, pos, ctx: ShardCtx | None = None):
    if cfg.is_encoder_decoder:
        return wsp.decode_step(cfg, params, tokens, cache, pos, ctx)
    return tfm.decode_step(cfg, params, tokens, cache, pos, ctx)


# ---------------------------------------------------------------------------
# dry-run stand-ins
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, seq_len: int, global_batch: int, mode: str):
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    mode: train | prefill | decode  (decode: one token + cache of seq_len)
    """
    B, S = global_batch, seq_len
    i32 = jnp.int32
    if mode in ("train", "prefill"):
        if cfg.is_encoder_decoder:
            tgt = min(S, cfg.max_target_len) if mode == "prefill" else min(S, 4096)
            return {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), CDTYPE),
                "tokens": jax.ShapeDtypeStruct((B, tgt), i32),
                "labels": jax.ShapeDtypeStruct((B, tgt), i32),
            }
        if cfg.frontend == "vision_stub":
            s_txt = S - cfg.num_patches
            return {
                "tokens": jax.ShapeDtypeStruct((B, s_txt), i32),
                "labels": jax.ShapeDtypeStruct((B, s_txt), i32),
                "patch_embeds": jax.ShapeDtypeStruct((B, cfg.num_patches, cfg.d_model), CDTYPE),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    if mode == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    raise ValueError(mode)


def scan_trip_hints(cfg: ModelConfig, seq_len: int, mode: str,
                    slstm_chunk: int = 1) -> list[int]:
    """Trip counts of the `while` loops of a lowered step, in nesting order
    (depth 1 first). Used by launch/hlo_analysis.py; see DESIGN.md §7."""
    if cfg.is_encoder_decoder:
        return [cfg.encoder_layers, cfg.num_layers]
    if cfg.family == "hybrid":
        return [cfg.num_layers // cfg.attn_period]
    if cfg.family == "ssm":
        # unrolled layers; each sLSTM block is one depth-1 time scan
        return [max(seq_len // max(slstm_chunk, 1), 1) if mode != "decode" else 1]
    return [cfg.num_layers]
