"""Mixture-of-Experts with expert parallelism over the `model` mesh axis.

Design (DESIGN.md §5): activations are replicated over `model` (the
Megatron invariant), so expert dispatch needs NO all-to-all — each device
locally gathers the tokens routed to the experts it owns, computes, and the
per-layer TP all-reduce (psum) combines expert outputs and d_ff shards in
one collective.

Virtual-expert layout: the E physical experts are laid out over the
``V = |model axis|`` devices as ``[V, E_loc, D, F_v]``:

* E >= V: each device owns ``E_loc = E/V`` full experts   (F_v = F)
* E <  V: each expert is split into ``V/E`` d_ff shards    (E_loc = 1,
  F_v = F*E/V); the shards of one expert gather the same tokens and the
  final psum sums their partial w_down outputs — numerically identical to
  the unsharded expert.

Capacity dispatch: per (device, physical expert), the ``C`` highest-router-
probability tokens of the LOCAL batch shard are kept (standard
prob-priority capacity policy, cf. GShard/Switch); dropped tokens pass
through the residual stream. C = ceil(T_loc * top_k / E * capacity_factor).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
try:
    from jax import shard_map
except ImportError:  # jax < 0.5 ships it under experimental only
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import dense_init
from .sharding import ShardCtx


def moe_layout(cfg: ModelConfig, V: int) -> tuple[int, int]:
    """(E_loc, F_v) for a given virtual-expert count V."""
    E, F = cfg.num_experts, cfg.d_ff
    if E >= V:
        if E % V:
            raise ValueError(f"num_experts {E} not divisible by mesh model axis {V}")
        return E // V, F
    if V % E or F % (V // E):
        raise ValueError(f"cannot split {E} experts / d_ff {F} over {V} devices")
    return 1, F * E // V


def moe_params(cfg: ModelConfig, key, V: int = 1):
    D, E = cfg.d_model, cfg.num_experts
    E_loc, F_v = moe_layout(cfg, V)
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (D, E)),
        "w_gate": dense_init(ks[1], (V, E_loc, D, F_v)),
        "w_up": dense_init(ks[2], (V, E_loc, D, F_v)),
        "w_down": dense_init(ks[3], (V, E_loc, F_v, D)),
    }


def _phys_expert_ids(cfg: ModelConfig, V: int, virt: jax.Array) -> jax.Array:
    """[E_loc] physical expert ids owned by virtual shard ``virt``."""
    E = cfg.num_experts
    E_loc, _ = moe_layout(cfg, V)
    if E >= V:
        return virt * E_loc + jnp.arange(E_loc, dtype=jnp.int32)
    return (virt // (V // E))[None].astype(jnp.int32)


def moe_ffn_shard(cfg: ModelConfig, x, router, w_gate, w_up, w_down, virt, V: int):
    """Per-shard MoE: x [T, D] local tokens; w_* [E_loc, D|F_v, F_v|D].

    Returns the PARTIAL output [T, D]; caller psums over the model axis.
    """
    T, D = x.shape
    E = cfg.num_experts
    E_loc, F_v = moe_layout(cfg, V)
    C = max(int(-(-T * cfg.top_k * cfg.capacity_factor // E)), 4)
    C = min(C, T)

    logits = (x @ router.astype(x.dtype)).astype(jnp.float32)      # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, cfg.top_k)            # [T, K]
    gates = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)   # renormalized

    mine = _phys_expert_ids(cfg, V, virt)                          # [E_loc]
    # score[e_loc, t] = gate if token t routed my expert e_loc else -inf
    hit = top_idx[None, :, :] == mine[:, None, None]               # [E_loc, T, K]
    score = jnp.max(jnp.where(hit, gates[None], -jnp.inf), axis=-1)  # [E_loc, T]
    cap_vals, cap_idx = jax.lax.top_k(score, C)                    # [E_loc, C]
    keep = jnp.isfinite(cap_vals)
    w_tok = jnp.where(keep, cap_vals, 0.0).astype(x.dtype)         # [E_loc, C]
    xe = jnp.take(x, jnp.where(keep, cap_idx, 0), axis=0)          # [E_loc, C, D]

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate.astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, w_up.astype(x.dtype))
    ye = jnp.einsum("ecf,efd->ecd", h, w_down.astype(x.dtype))     # [E_loc, C, D]
    ye = ye * w_tok[..., None]

    out = jnp.zeros((T, D), x.dtype)
    out = out.at[cap_idx.reshape(-1)].add(
        ye.reshape(-1, D), mode="drop",
        indices_are_sorted=False, unique_indices=False,
    )
    return out


def apply_moe(cfg: ModelConfig, p, x, ctx: ShardCtx | None):
    """x [B, S, D] -> [B, S, D]."""
    B, S, D = x.shape

    if ctx is None or ctx.model_size == 1:
        out = moe_ffn_shard(
            cfg, x.reshape(-1, D), p["router"], p["w_gate"][0], p["w_up"][0],
            p["w_down"][0], jnp.asarray(0, jnp.int32), V=1,
        )
        return out.reshape(B, S, D)

    V = ctx.model_size
    from .sharding import batch_spec as _bspec
    bspec = _bspec(ctx)
    maxis = ctx.model_axis
    wspec = P(maxis, None, "data" if ctx.zero3 else None, None)

    def shard_fn(xs, router, wg, wu, wd):
        # xs [B_loc, S, D] replicated over model; w* [1, E_loc, D(/dp), F_v]
        virt = jax.lax.axis_index(maxis)
        if ctx.zero3:
            wg = jax.lax.all_gather(wg, "data", axis=2, tiled=True)
            wu = jax.lax.all_gather(wu, "data", axis=2, tiled=True)
            wd = jax.lax.all_gather(wd, "data", axis=3, tiled=True)
        out = moe_ffn_shard(
            cfg, xs.reshape(-1, D), router, wg[0], wu[0], wd[0], virt, V=V
        )
        return jax.lax.psum(out.reshape(xs.shape), maxis)

    return shard_map(
        shard_fn,
        mesh=ctx.mesh,
        in_specs=(
            P(bspec, None, None),
            P(None, None),
            wspec, wspec,
            P(maxis, None, None, "data" if ctx.zero3 else None),
        ),
        out_specs=P(bspec, None, None),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
