"""GQA attention: training (full/sliding-window/cross) and decode paths."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import CDTYPE, apply_rope, dense_init, rope_angles

NEG = -1e30


def attn_params(cfg: ModelConfig, key):
    D, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, qd)),
        "wk": dense_init(ks[1], (D, kvd)),
        "wv": dense_init(ks[2], (D, kvd)),
        "wo": dense_init(ks[3], (qd, D)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), jnp.float32)
        p["bk"] = jnp.zeros((kvd,), jnp.float32)
        p["bv"] = jnp.zeros((kvd,), jnp.float32)
    return p


def _project_qkv(cfg: ModelConfig, p, x):
    B, S, _ = x.shape
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def _expand_kv(cfg: ModelConfig, k):
    """[B,S,Hkv,Dh] -> [B,S,H,Dh] by repeating each kv head."""
    rep = cfg.num_heads // cfg.num_kv_heads
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def _sdpa(q, k, v, mask, bf16: bool = False):
    """q [B,Sq,H,Dh], k/v [B,Sk,H,Dh], mask [1|B, Sq, Sk] bool (True=keep).

    ``bf16``: compute QK^T in bf16 and upcast only for the softmax — the
    VJP then carries bf16 cotangents through both einsums (halves attention
    traffic and the TP all-reduce payloads in backward; §Perf H1)."""
    scale = q.shape[-1] ** -0.5
    if bf16:
        logits = (jnp.einsum("bqhd,bkhd->bhqk", q, k) * jnp.asarray(scale, q.dtype)).astype(jnp.float32)
    else:
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    logits = jnp.where(mask[:, None, :, :], logits, NEG)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def self_attention(cfg: ModelConfig, p, x, *, causal: bool, positions=None,
                   bf16: bool = False, ctx=None):
    """Training/prefill self-attention. Returns (out [B,S,D], (k, v))."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x)
    if ctx is not None and ctx.attn_seq_shard:
        # context parallelism: logits [B,H,Sq/|model|,Sk] — softmax is local
        # to each shard, k/v are gathered once per layer (cheap vs logits)
        from .sharding import batch_spec
        bs = batch_spec(ctx)
        q = ctx.constrain(q, bs, "model", None, None)
        k = ctx.constrain(k, bs, None, None, None)
        v = ctx.constrain(v, bs, None, None, None)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if cfg.rope_theta > 0:
        cos, sin = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
        if bf16:  # angles stay f32; rotation runs in compute dtype
            cos, sin = cos.astype(x.dtype), sin.astype(x.dtype)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    if ctx is not None and ctx.use_flash:
        from repro.kernels.ops import flash_attention
        out = flash_attention(q, k, v, causal=causal,
                              window=cfg.sliding_window)
    else:
        iq = jnp.arange(S)[:, None]
        ik = jnp.arange(S)[None, :]
        mask = jnp.ones((1, S, S), bool)
        if causal:
            mask = mask & (ik <= iq)[None]
        if cfg.sliding_window > 0:
            mask = mask & (iq - ik < cfg.sliding_window)[None]
        out = _sdpa(q, _expand_kv(cfg, k), _expand_kv(cfg, v), mask, bf16=bf16)
    out = out.reshape(B, S, cfg.q_dim) @ p["wo"].astype(x.dtype)
    return out, (k, v)


def cross_attention(cfg: ModelConfig, p, x, memory_kv):
    """Decoder cross-attention against precomputed encoder (k, v)."""
    B, S, _ = x.shape
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k, v = memory_kv
    mask = jnp.ones((1, S, k.shape[1]), bool)
    out = _sdpa(q, _expand_kv(cfg, k), _expand_kv(cfg, v), mask)
    return out.reshape(B, S, cfg.q_dim) @ p["wo"].astype(x.dtype)


def decode_attention(cfg: ModelConfig, p, x, cache_k, cache_v, pos):
    """One-token decode. x [B,1,D]; cache_k/v [B, Smax, Hkv, Dh]; pos [] i32.

    The KV cache is a plain ring-free buffer for full attention and a ring
    buffer (index mod window) for sliding-window attention, so the cache for
    `long_500k` is O(window), not O(seq).
    """
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(cfg, p, x)  # S == 1
    if cfg.rope_theta > 0:
        posv = jnp.full((B, 1), pos)
        cos, sin = rope_angles(posv, cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k_new = apply_rope(k_new, cos, sin)
    Smax = cache_k.shape[1]
    slot = pos % Smax if cfg.sliding_window > 0 else pos
    cache_k = jax.lax.dynamic_update_slice(cache_k, k_new, (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v_new, (0, slot, 0, 0))
    ik = jnp.arange(Smax)[None, :]
    if cfg.sliding_window > 0:
        # valid ring slots: the last min(pos+1, Smax) written entries
        age = (slot - ik) % Smax
        mask = (age <= jnp.minimum(pos, Smax - 1))[:, None, :]
    else:
        mask = (ik <= pos)[:, None, :]
    out = _sdpa(q, _expand_kv(cfg, cache_k), _expand_kv(cfg, cache_v), mask)
    out = out.reshape(B, 1, cfg.q_dim) @ p["wo"].astype(x.dtype)
    return out, cache_k, cache_v


def decode_cross_attention(cfg: ModelConfig, p, x, memory_kv):
    return cross_attention(cfg, p, x, memory_kv)
