"""xLSTM blocks: mLSTM (parallel chunkwise, matrix memory) and sLSTM
(sequential scan with memory mixing).

TPU adaptation: mLSTM's quadratic/chunkwise form maps to MXU einsums with
an associative scan carrying the (C, n) matrix memory across chunks — no
while loop. sLSTM's memory mixing is inherently sequential (the paper says
so), so it is a `lax.scan` over time; its per-step work is a block-diagonal
matmul batched over heads. Stabilization uses the xLSTM m-state in log
space (clipped for the chunkwise weights).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, rmsnorm

CLIP = 30.0


def _heads(cfg: ModelConfig) -> tuple[int, int]:
    H = cfg.num_heads
    return H, cfg.d_model // H


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_params(cfg: ModelConfig, key):
    D = cfg.d_model
    H, P = _heads(cfg)
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (D, D)),
        "wk": dense_init(ks[1], (D, D)),
        "wv": dense_init(ks[2], (D, D)),
        "wi": dense_init(ks[3], (D, H), scale=0.01),
        "wf": dense_init(ks[4], (D, H), scale=0.01),
        "bf": jnp.full((H,), 3.0, jnp.float32),  # forget-gate bias -> ~1
        "wo": dense_init(ks[5], (D, D)),
        "norm": jnp.zeros((D,), jnp.float32),
    }


def apply_mlstm(cfg: ModelConfig, p, x, chunk: int = 256):
    """x [B,S,D] -> [B,S,D], chunkwise parallel form."""
    B, S, D = x.shape
    H, P = _heads(cfg)
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, P)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, H, P) / jnp.sqrt(P).astype(x.dtype)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, H, P)
    logi = (x @ p["wi"].astype(x.dtype)).astype(jnp.float32)                     # [B,S,H]
    logf = jax.nn.log_sigmoid((x @ p["wf"].astype(x.dtype)).astype(jnp.float32) + p["bf"])

    qc = q.reshape(B, nc, chunk, H, P).astype(jnp.float32)
    kc = k.reshape(B, nc, chunk, H, P).astype(jnp.float32)
    vc = v.reshape(B, nc, chunk, H, P).astype(jnp.float32)
    lic = logi.reshape(B, nc, chunk, H)
    cumf = jnp.cumsum(logf.reshape(B, nc, chunk, H), axis=2)                     # [B,nc,c,H]

    # intra-chunk: w_ij = exp(cumf_i - cumf_j + logi_j), i >= j
    Dij = cumf[:, :, :, None, :] - cumf[:, :, None, :, :] + lic[:, :, None, :, :]
    tri = jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :]
    W = jnp.where(tri[None, None, :, :, None], jnp.exp(jnp.clip(Dij, -CLIP, CLIP)), 0.0)
    att = jnp.einsum("bgihp,bgjhp->bgijh", qc, kc) * W                           # [B,nc,i,j,H]
    y_intra = jnp.einsum("bgijh,bgjhp->bgihp", att, vc)
    n_intra = jnp.sum(att, axis=3)                                               # [B,nc,i,H] row mass

    # inter-chunk: matrix memory C [B,H,P,P], mass n [B,H,P]
    dec_out = jnp.exp(jnp.clip(cumf[:, :, -1:, :] - cumf + lic, -CLIP, CLIP))    # [B,nc,c,H]
    Cg = jnp.einsum("bgjhp,bgjh,bgjhq->bghpq", kc, dec_out, vc)                  # kv^T sums
    ng = jnp.einsum("bgjhp,bgjh->bghp", kc, dec_out)
    Ag = jnp.exp(jnp.clip(cumf[:, :, -1, :], -CLIP, CLIP))                       # [B,nc,H]

    def combine(a, b):
        A1, C1, n1 = a
        A2, C2, n2 = b
        return A1 * A2, A2[..., None, None] * C1 + C2, A2[..., None] * n1 + n2

    Acum, Ccum, ncum = jax.lax.associative_scan(combine, (Ag, Cg, ng), axis=1)
    C_prev = jnp.concatenate([jnp.zeros_like(Ccum[:, :1]), Ccum[:, :-1]], axis=1)
    n_prev = jnp.concatenate([jnp.zeros_like(ncum[:, :1]), ncum[:, :-1]], axis=1)
    gi = jnp.exp(jnp.clip(cumf, -CLIP, CLIP))                                    # [B,nc,c,H]
    y_inter = jnp.einsum("bgihp,bgih,bghpq->bgihq", qc, gi, C_prev)
    n_inter = jnp.einsum("bgihp,bgih,bghp->bgih", qc, gi, n_prev)

    # normalizer: |sum_j w_ij (q_i . k_j)| accumulated mass, floored at 1
    denom = jnp.maximum(jnp.abs(n_intra + n_inter), 1.0)[..., None]
    y = (y_intra + y_inter) / denom
    y = y.reshape(B, S, H, P)
    # per-head RMS norm, then output proj
    y = rmsnorm(y.reshape(B, S, D).astype(x.dtype), p["norm"])
    return y @ p["wo"].astype(x.dtype)


def mlstm_state_init(cfg: ModelConfig, batch: int):
    H, P = _heads(cfg)
    return {
        "C": jnp.zeros((batch, H, P, P), jnp.float32),
        "n": jnp.zeros((batch, H, P), jnp.float32),
        "f_acc": jnp.zeros((batch, H), jnp.float32),
    }


def decode_mlstm(cfg: ModelConfig, p, x, state):
    B = x.shape[0]
    D = cfg.d_model
    H, P = _heads(cfg)
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, H, P).astype(jnp.float32)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, H, P).astype(jnp.float32) / jnp.sqrt(P)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, H, P).astype(jnp.float32)
    logi = (x @ p["wi"].astype(x.dtype)).astype(jnp.float32)[:, 0]
    logf = jax.nn.log_sigmoid((x @ p["wf"].astype(x.dtype)).astype(jnp.float32) + p["bf"])[:, 0]
    fa = jnp.exp(jnp.clip(logf, -CLIP, CLIP))
    ia = jnp.exp(jnp.clip(logi, -CLIP, CLIP))
    C = fa[..., None, None] * state["C"] + ia[..., None, None] * jnp.einsum("bhp,bhq->bhpq", k, v)
    n = fa[..., None] * state["n"] + ia[..., None] * k
    num = jnp.einsum("bhp,bhpq->bhq", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", q, n)), 1.0)[..., None]
    y = (num / den).reshape(B, 1, D).astype(x.dtype)
    y = rmsnorm(y, p["norm"])
    return y @ p["wo"].astype(x.dtype), {"C": C, "n": n, "f_acc": state["f_acc"]}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_params(cfg: ModelConfig, key):
    D = cfg.d_model
    H, P = _heads(cfg)
    ks = jax.random.split(key, 3)
    return {
        "W": dense_init(ks[0], (D, 4 * D)),          # z, i, f, o pre-activations
        "R": dense_init(ks[1], (H, P, 4 * P), scale=0.5 / jnp.sqrt(P)),  # block-diag recurrent
        "b": jnp.zeros((4 * D,), jnp.float32),
        "norm": jnp.zeros((D,), jnp.float32),
        "wo": dense_init(ks[2], (D, D)),
    }


def slstm_state_init(cfg: ModelConfig, batch: int):
    H, P = _heads(cfg)
    return {
        "c": jnp.zeros((batch, H, P), jnp.float32),
        "n": jnp.ones((batch, H, P), jnp.float32),
        "h": jnp.zeros((batch, H, P), jnp.float32),
        "m": jnp.zeros((batch, H, P), jnp.float32),
    }


def _slstm_step(cfg: ModelConfig, p, wx_t, state):
    """wx_t [B, 4D] precomputed W x_t + b; state pytree of [B,H,P]."""
    H, P = _heads(cfg)
    B = wx_t.shape[0]
    rh = jnp.einsum("bhp,hpq->bhq", state["h"].astype(wx_t.dtype), p["R"].astype(wx_t.dtype))
    pre = (wx_t.reshape(B, H, 4 * P) + rh).astype(jnp.float32)
    z, i, f, o = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o)
    logf = jax.nn.log_sigmoid(f)
    m_new = jnp.maximum(logf + state["m"], i)
    ig = jnp.exp(i - m_new)
    fg = jnp.exp(logf + state["m"] - m_new)
    c = fg * state["c"] + ig * z
    n = jnp.maximum(fg * state["n"] + ig, 1e-6)
    h = o * (c / n)
    return {"c": c, "n": n, "h": h, "m": m_new}


def apply_slstm(cfg: ModelConfig, p, x, time_chunk: int = 1):
    """x [B,S,D] -> [B,S,D]; sequential lax.scan over time.

    ``time_chunk`` > 1 processes that many timesteps per scan iteration
    (inner python-unrolled): the recurrence stays exact, but the recurrent
    weights R are fetched from HBM once per ITERATION instead of once per
    STEP — an HBM-traffic optimization for the memory-bound sLSTM
    (EXPERIMENTS §Perf, xlstm plan)."""
    B, S, D = x.shape
    H, P = _heads(cfg)
    wx = x @ p["W"].astype(x.dtype) + p["b"].astype(x.dtype)   # [B,S,4D]
    state0 = slstm_state_init(cfg, B)
    tc = max(int(time_chunk), 1)
    assert S % tc == 0, "seq must divide the sLSTM time chunk"

    def step(state, wx_ts):  # wx_ts [tc, B, 4D]
        hs = []
        for t in range(tc):
            state = _slstm_step(cfg, p, wx_ts[t], state)
            hs.append(state["h"])
        return state, jnp.stack(hs)

    xs = jnp.swapaxes(wx, 0, 1).reshape(S // tc, tc, B, 4 * D)
    _, hs = jax.lax.scan(step, state0, xs)
    y = jnp.swapaxes(hs.reshape(S, B, H, P), 0, 1).reshape(B, S, D).astype(x.dtype)
    y = rmsnorm(y, p["norm"])
    return y @ p["wo"].astype(x.dtype)


def decode_slstm(cfg: ModelConfig, p, x, state):
    B = x.shape[0]
    D = cfg.d_model
    wx = (x @ p["W"].astype(x.dtype) + p["b"].astype(x.dtype))[:, 0]
    new = _slstm_step(cfg, p, wx, state)
    y = new["h"].reshape(B, 1, D).astype(x.dtype)
    y = rmsnorm(y, p["norm"])
    return y @ p["wo"].astype(x.dtype), new
