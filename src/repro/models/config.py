"""Model configuration shared by the whole zoo."""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0                 # 0 -> d_model // num_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    act: str = "silu"                 # silu (SwiGLU) | gelu (plain MLP)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_period: int = 1               # MoE every `moe_period`-th layer

    # --- attention variants --------------------------------------------------
    sliding_window: int = 0           # 0 = full attention

    # --- hybrid (jamba) -------------------------------------------------------
    attn_period: int = 0              # 1 attention layer per `attn_period`
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # --- ssm (xlstm) -----------------------------------------------------------
    slstm_every: int = 2              # sLSTM every n-th layer (rest mLSTM)

    # --- encoder-decoder (whisper) ---------------------------------------------
    encoder_layers: int = 0
    max_target_len: int = 448

    # --- modality frontends (STUBS by assignment) -------------------------------
    frontend: str = "none"            # none | audio_stub | vision_stub
    num_patches: int = 256            # vlm: patch embeddings prepended

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode at 500k context? (assignment's long_500k rule)"""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def layer_kinds(self) -> list[str]:
        """Sub-layer kinds of one scan super-block (see transformer.py)."""
        if self.family == "hybrid":
            kinds = []
            for i in range(self.attn_period):
                kind = "attn" if i == self.attn_period - 1 else "mamba"
                ff = "moe" if (i % 2 == 1 and self.is_moe) else "mlp"
                kinds.append(f"{kind}+{ff}")
            return kinds
        if self.family == "ssm":
            return ["slstm" if i % self.slstm_every == 1 else "mlstm"
                    for i in range(self.num_layers)]
        ff = "moe" if self.is_moe else "mlp"
        return [f"attn+{ff}"]

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        qd, kvd = self.q_dim, self.kv_dim
        attn = D * qd + 2 * D * kvd + qd * D
        if self.qkv_bias:
            attn += qd + 2 * kvd
        if self.act == "silu":
            mlp = 3 * D * F
        else:
            mlp = 2 * D * F
        per_layer = 2 * D  # norms
        if self.family == "ssm":
            # xlstm block ~ qkv + gates + out proj (approximation documented)
            per_layer += 4 * D * D + 4 * D
            blocks = self.num_layers * per_layer
        elif self.family == "hybrid":
            d_in = self.mamba_expand * D
            mamba = D * 2 * d_in + d_in * self.mamba_d_conv + d_in * (self.mamba_d_state * 2 + 1) + d_in * D
            n_attn = self.num_layers // self.attn_period
            n_mamba = self.num_layers - n_attn
            n_moe = self.num_layers // 2 if self.is_moe else 0
            n_mlp = self.num_layers - n_moe
            blocks = (n_attn * attn + n_mamba * mamba
                      + n_moe * self.num_experts * mlp + n_mlp * mlp
                      + self.num_layers * 2 * D)
        elif self.is_moe:
            blocks = self.num_layers * (attn + self.num_experts * mlp + D * self.num_experts + per_layer)
        else:
            blocks = self.num_layers * (attn + mlp + per_layer)
        emb = V * D * (1 if self.tie_embeddings else 2)
        enc = self.encoder_layers * (attn + mlp + per_layer) if self.is_encoder_decoder else 0
        # cross attention for enc-dec decoders
        if self.is_encoder_decoder:
            blocks += self.num_layers * attn
        return int(emb + blocks + enc)

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top_k of num_experts)."""
        if not self.is_moe:
            return self.param_count()
        D, F = self.d_model, self.d_ff
        mlp = (3 if self.act == "silu" else 2) * D * F
        if self.family == "hybrid":
            n_moe = self.num_layers // 2
        else:
            n_moe = self.num_layers // self.moe_period
        inactive = n_moe * (self.num_experts - self.top_k) * mlp
        return int(self.param_count() - inactive)
