"""Decoder-only LM assembly for dense / MoE / hybrid / SSM / VLM families.

Layer stacks are `lax.scan`s over stacked parameter pytrees (one compiled
layer body — this is also what keeps the multi-pod dry-run and the HLO
roofline analysis tractable: exactly one `while` per homogeneous stack).

* dense/moe : scan over L identical blocks
* hybrid    : scan over L/attn_period super-blocks (Jamba 1:7 pattern,
              MoE every 2nd sub-layer, unrolled inside the body)
* ssm       : unrolled (12 small xLSTM blocks; sLSTM time-scan inside)
* vlm       : text tokens + precomputed patch embeddings (frontend stub)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mamba as mb
from . import xlstm as xl
from .config import ModelConfig
from .layers import (CDTYPE, apply_mlp, apply_norm, embed_params, embed_tokens,
                     mlp_params, norm_params, softmax_xent, unembed)
from .moe import apply_moe, moe_params
from .sharding import ShardCtx, batch_spec, constrain


# ---------------------------------------------------------------------------
# per-block params
# ---------------------------------------------------------------------------

def _block_params(cfg: ModelConfig, key, kind: str, V: int):
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": norm_params(cfg, ks[0]), "norm2": norm_params(cfg, ks[1])}
    mixer, ff = (kind.split("+") + ["none"])[:2]
    if mixer == "attn":
        p["attn"] = attn.attn_params(cfg, ks[2])
    elif mixer == "mamba":
        p["mamba"] = mb.mamba_params(cfg, ks[2])
    elif mixer == "mlstm":
        p["mlstm"] = xl.mlstm_params(cfg, ks[2])
    elif mixer == "slstm":
        p["slstm"] = xl.slstm_params(cfg, ks[2])
    if ff == "mlp":
        p["mlp"] = mlp_params(cfg, ks[3])
    elif ff == "moe":
        p["moe"] = moe_params(cfg, ks[3], V=V)
    return p


def _seq_ax(ctx: ShardCtx | None):
    return "model" if (ctx is not None and ctx.attn_seq_shard) else None


def _apply_block(cfg: ModelConfig, p, x, kind: str, ctx: ShardCtx | None):
    bs = batch_spec(ctx)
    sq = _seq_ax(ctx)
    mixer, ff = (kind.split("+") + ["none"])[:2]
    h = apply_norm(cfg, p["norm1"], x)
    if mixer == "attn":
        out, _ = attn.self_attention(cfg, p["attn"], h, causal=True,
                                     bf16=bool(ctx and ctx.bf16_attn), ctx=ctx)
    elif mixer == "mamba":
        out = mb.apply_mamba(cfg, p["mamba"], h)
    elif mixer == "mlstm":
        out = xl.apply_mlstm(cfg, p["mlstm"], h)
    elif mixer == "slstm":
        out = xl.apply_slstm(cfg, p["slstm"], h,
                             time_chunk=(ctx.slstm_chunk if ctx else 1))
    else:
        raise ValueError(kind)
    x = x + constrain(ctx, out, bs, _seq_ax(ctx), None)
    if ff == "none":
        return x
    h = apply_norm(cfg, p["norm2"], x)
    if ff == "moe":
        out = apply_moe(cfg, p["moe"], h, ctx)
    else:
        out = apply_mlp(cfg, p["mlp"], h)
    return x + constrain(ctx, out, bs, _seq_ax(ctx), None)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stack_params(cfg: ModelConfig, key, kind: str, n: int, V: int):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _block_params(cfg, k, kind, V))(keys)


def init_params(cfg: ModelConfig, key, V: int = 1):
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = {"embed": embed_params(cfg, ks[0]), "final_norm": norm_params(cfg, ks[1])}
    kinds = cfg.layer_kinds()
    if cfg.family == "hybrid":
        n_super = cfg.num_layers // cfg.attn_period
        sub = {}
        for i, kind in enumerate(kinds):
            sub[f"sub{i}"] = _stack_params(cfg, jax.random.fold_in(ks[2], i), kind, n_super, V)
        params["blocks"] = sub
    elif cfg.family == "ssm":
        for i, kind in enumerate(kinds):
            params[f"layer{i}"] = _block_params(cfg, jax.random.fold_in(ks[2], i), kind, V)
    else:
        params["blocks"] = _stack_params(cfg, ks[2], kinds[0], cfg.num_layers, V)
    if cfg.frontend == "vision_stub":
        params["patch_proj"] = jnp.eye(cfg.d_model, dtype=jnp.float32)  # stub projector
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def backbone(cfg: ModelConfig, params, x, ctx: ShardCtx | None, remat: bool = True):
    """x [B,S,D] -> [B,S,D] hidden states."""
    kinds = cfg.layer_kinds()
    bs = batch_spec(ctx)
    x = constrain(ctx, x, bs, _seq_ax(ctx), None)

    ckpt_kwargs = {}
    if ctx is not None and ctx.remat == "dots":
        ckpt_kwargs["policy"] = jax.checkpoint_policies.dots_with_no_batch_dims_saveable

    if ctx is not None and ctx.cast_params_once and "blocks" in params:
        # cast sharded master weights to compute dtype OUTSIDE the scan:
        # the per-layer ZeRO-3 all-gather then moves bf16 payloads (H3).
        from .layers import CDTYPE as _CD
        params = dict(params)
        params["blocks"] = jax.tree.map(
            lambda p: p.astype(_CD) if p.dtype == jnp.float32 else p,
            params["blocks"])

    if cfg.family == "ssm":
        for i, kind in enumerate(kinds):
            fn = functools.partial(_apply_block, cfg, kind=kind, ctx=ctx)
            if remat:
                fn = jax.checkpoint(fn, **ckpt_kwargs)
            x = fn(params[f"layer{i}"], x)
    elif cfg.family == "hybrid":
        def body(h, layer_p):
            for i, kind in enumerate(kinds):
                h = _apply_block(cfg, layer_p[f"sub{i}"], h, kind, ctx)
            return h, ()
        if remat:
            body = jax.checkpoint(body, **ckpt_kwargs)
        x, _ = jax.lax.scan(body, x, params["blocks"])
    else:
        kind = kinds[0]
        def body(h, layer_p):
            return _apply_block(cfg, layer_p, h, kind, ctx), ()
        if remat:
            body = jax.checkpoint(body, **ckpt_kwargs)
        x, _ = jax.lax.scan(body, x, params["blocks"])
    return apply_norm(cfg, params["final_norm"], x)


def embed_inputs(cfg: ModelConfig, params, batch, ctx: ShardCtx | None):
    """Token (and stub-modality) embedding. Returns (x [B,S,D], loss mask)."""
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens)
    mask = jnp.ones(tokens.shape, jnp.float32)
    if cfg.frontend == "vision_stub":
        patches = batch["patch_embeds"].astype(CDTYPE) @ params["patch_proj"].astype(CDTYPE)
        x = jnp.concatenate([patches, x], axis=1)
        mask = jnp.concatenate([jnp.zeros(patches.shape[:2], jnp.float32), mask], axis=1)
    return x, mask


def lm_loss(cfg: ModelConfig, params, batch, ctx: ShardCtx | None = None):
    """Next-token cross-entropy. batch: tokens [B,S], labels [B,S] (+stubs)."""
    x, mask = embed_inputs(cfg, params, batch, ctx)
    h = backbone(cfg, params, x, ctx)
    if cfg.frontend == "vision_stub":
        h = h[:, -batch["tokens"].shape[1]:, :]  # loss over text positions
        mask = mask[:, -batch["tokens"].shape[1]:]
    logits = unembed(cfg, params["embed"], h)
    bs = batch_spec(ctx)
    if _seq_ax(ctx):
        logits = constrain(ctx, logits, bs, "model", None)
    else:
        logits = constrain(ctx, logits, bs, None, "model")
    return softmax_xent(logits, batch["labels"], mask)


# ---------------------------------------------------------------------------
# decode (one token) + prefill
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, V: int = 1):
    """Decode cache pytree. Attention layers get [B, Smax(|window), Hkv, Dh];
    SSM layers get recurrent states (O(1) in sequence length)."""
    S = min(max_len, cfg.sliding_window) if cfg.sliding_window > 0 else max_len
    kv = lambda: {
        "k": jnp.zeros((batch, S, cfg.num_kv_heads, cfg.head_dim), CDTYPE),
        "v": jnp.zeros((batch, S, cfg.num_kv_heads, cfg.head_dim), CDTYPE),
    }
    kinds = cfg.layer_kinds()
    if cfg.family == "hybrid":
        n_super = cfg.num_layers // cfg.attn_period
        def stack(tree):
            return jax.tree.map(lambda a: jnp.broadcast_to(a, (n_super,) + a.shape), tree)
        sub = {}
        for i, kind in enumerate(kinds):
            mixer = kind.split("+")[0]
            sub[f"sub{i}"] = stack(kv() if mixer == "attn" else mb.mamba_state_init(cfg, batch))
        return sub
    if cfg.family == "ssm":
        return {
            f"layer{i}": (xl.slstm_state_init(cfg, batch) if k == "slstm"
                          else xl.mlstm_state_init(cfg, batch))
            for i, k in enumerate(kinds)
        }
    def stack(tree):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape), tree)
    return stack(kv())


def _decode_block(cfg: ModelConfig, p, x, kind: str, cache, pos, ctx):
    mixer, ff = (kind.split("+") + ["none"])[:2]
    h = apply_norm(cfg, p["norm1"], x)
    if mixer == "attn":
        out, ck, cv = attn.decode_attention(cfg, p["attn"], h, cache["k"], cache["v"], pos)
        cache = {"k": ck, "v": cv}
    elif mixer == "mamba":
        out, cache = mb.decode_mamba(cfg, p["mamba"], h, cache)
    elif mixer == "mlstm":
        out, cache = xl.decode_mlstm(cfg, p["mlstm"], h, cache)
    elif mixer == "slstm":
        out, cache = xl.decode_slstm(cfg, p["slstm"], h, cache)
    x = x + out
    if ff != "none":
        h = apply_norm(cfg, p["norm2"], x)
        out = apply_moe(cfg, p["moe"], h, ctx) if ff == "moe" else apply_mlp(cfg, p["mlp"], h)
        x = x + out
    return x, cache


def decode_step(cfg: ModelConfig, params, tokens, cache, pos, ctx: ShardCtx | None = None):
    """tokens [B,1] -> (logits [B,1,V], new cache). pos: current position."""
    kinds = cfg.layer_kinds()
    x = embed_tokens(params["embed"], tokens)
    if cfg.family == "ssm":
        new_cache = {}
        for i, kind in enumerate(kinds):
            x, new_cache[f"layer{i}"] = _decode_block(
                cfg, params[f"layer{i}"], x, kind, cache[f"layer{i}"], pos, ctx)
    elif cfg.family == "hybrid":
        def body(h, scanned):
            layer_p, layer_c = scanned
            new_c = {}
            for i, kind in enumerate(kinds):
                h, new_c[f"sub{i}"] = _decode_block(
                    cfg, layer_p[f"sub{i}"], h, kind, layer_c[f"sub{i}"], pos, ctx)
            return h, new_c
        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    else:
        kind = kinds[0]
        def body(h, scanned):
            layer_p, layer_c = scanned
            h, new_c = _decode_block(cfg, layer_p, h, kind, layer_c, pos, ctx)
            return h, new_c
        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], x)
    return logits, new_cache


def prefill(cfg: ModelConfig, params, batch, ctx: ShardCtx | None = None):
    """Prefill forward: returns last-position logits (cache write elided —
    the dry-run measures the dominant compute; see serve/engine.py for the
    cache-materializing version used at small scale)."""
    x, _ = embed_inputs(cfg, params, batch, ctx)
    h = backbone(cfg, params, x, ctx, remat=False)
    logits = unembed(cfg, params["embed"], h[:, -1:, :])
    return logits
