"""Whisper-style encoder-decoder backbone (conv frontend is a STUB by
assignment: ``input_specs`` supplies precomputed frame embeddings)."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn
from .config import ModelConfig
from .layers import (CDTYPE, apply_mlp, apply_norm, dense_init, embed_params,
                     embed_tokens, mlp_params, norm_params, softmax_xent, unembed)
from .sharding import ShardCtx, batch_spec, constrain


def _enc_block_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 4)
    return {
        "norm1": norm_params(cfg, ks[0]),
        "attn": attn.attn_params(cfg, ks[1]),
        "norm2": norm_params(cfg, ks[2]),
        "mlp": mlp_params(cfg, ks[3]),
    }


def _dec_block_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 6)
    return {
        "norm1": norm_params(cfg, ks[0]),
        "attn": attn.attn_params(cfg, ks[1]),
        "norm2": norm_params(cfg, ks[2]),
        "xattn": attn.attn_params(cfg, ks[3]),
        "norm3": norm_params(cfg, ks[4]),
        "mlp": mlp_params(cfg, ks[5]),
    }


def init_params(cfg: ModelConfig, key, V: int = 1):
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embed": embed_params(cfg, ks[2]),
        "pos_enc": dense_init(ks[3], (8192, cfg.d_model), scale=0.01),
        "pos_dec": dense_init(ks[4], (cfg.max_target_len, cfg.d_model), scale=0.01),
        "enc": jax.vmap(lambda k: _enc_block_params(cfg, k))(enc_keys),
        "dec": jax.vmap(lambda k: _dec_block_params(cfg, k))(dec_keys),
        "enc_norm": norm_params(cfg, ks[5]),
        "final_norm": norm_params(cfg, ks[5]),
    }


def encode(cfg: ModelConfig, params, frames, ctx: ShardCtx | None):
    """frames [B, T, D] (stub conv output) -> encoder states [B, T, D]."""
    bs = batch_spec(ctx)
    T = frames.shape[1]
    pos = params["pos_enc"]
    if T > pos.shape[0]:  # long-prefill shapes: tile the table (stub-safe)
        reps = -(-T // pos.shape[0])
        pos = jnp.tile(pos, (reps, 1))
    x = frames.astype(CDTYPE) + pos[:T].astype(CDTYPE)[None]
    x = constrain(ctx, x, bs, None, None)

    def body(h, layer_p):
        a = apply_norm(cfg, layer_p["norm1"], h)
        out, _ = attn.self_attention(cfg, layer_p["attn"], a, causal=False)
        h = h + constrain(ctx, out, bs, None, None)
        a = apply_norm(cfg, layer_p["norm2"], h)
        return h + constrain(ctx, apply_mlp(cfg, layer_p["mlp"], a), bs, None, None), ()

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc"])
    return apply_norm(cfg, params["enc_norm"], x)


def decode_train(cfg: ModelConfig, params, tokens, memory, ctx: ShardCtx | None):
    """Teacher-forced decoder. tokens [B,S]; memory [B,T,D]."""
    bs = batch_spec(ctx)
    S = tokens.shape[1]
    pos = params["pos_dec"]
    if S > pos.shape[0]:
        pos = jnp.tile(pos, (-(-S // pos.shape[0]), 1))
    x = embed_tokens(params["embed"], tokens) + pos[:S].astype(CDTYPE)[None]

    # precompute shared memory K/V once per layer inside the scan body
    def body(h, layer_p):
        a = apply_norm(cfg, layer_p["norm1"], h)
        out, _ = attn.self_attention(cfg, layer_p["attn"], a, causal=True)
        h = h + constrain(ctx, out, bs, None, None)
        a = apply_norm(cfg, layer_p["norm2"], h)
        B, T, _ = memory.shape
        mk = (memory @ layer_p["xattn"]["wk"].astype(memory.dtype)).reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
        mv = (memory @ layer_p["xattn"]["wv"].astype(memory.dtype)).reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
        out = attn.cross_attention(cfg, layer_p["xattn"], a, (mk, mv))
        h = h + constrain(ctx, out, bs, None, None)
        a = apply_norm(cfg, layer_p["norm3"], h)
        return h + constrain(ctx, apply_mlp(cfg, layer_p["mlp"], a), bs, None, None), ()

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec"])
    return apply_norm(cfg, params["final_norm"], x)


def seq2seq_loss(cfg: ModelConfig, params, batch, ctx: ShardCtx | None = None):
    """batch: frames [B,T,D] (stub), tokens [B,S], labels [B,S]."""
    memory = encode(cfg, params, batch["frames"], ctx)
    h = decode_train(cfg, params, batch["tokens"], memory, ctx)
    logits = unembed(cfg, params["embed"], h)
    logits = constrain(ctx, logits, batch_spec(ctx), None, "model")
    return softmax_xent(logits, batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, max_len: int, V: int = 1):
    """Self-attn KV cache for the decoder + cross-attn memory K/V."""
    return {
        "self": {
            "k": jnp.zeros((cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim), CDTYPE),
            "v": jnp.zeros((cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim), CDTYPE),
        },
        "mem_kv": None,  # filled by prefill_memory below (shape depends on T)
    }


def prefill_memory(cfg: ModelConfig, params, frames, ctx: ShardCtx | None = None):
    """Encode audio and precompute cross-attention K/V per decoder layer."""
    memory = encode(cfg, params, frames, ctx)
    B, T, _ = memory.shape

    def per_layer(layer_p):
        mk = (memory @ layer_p["xattn"]["wk"].astype(memory.dtype)).reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
        mv = (memory @ layer_p["xattn"]["wv"].astype(memory.dtype)).reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
        return mk, mv

    return jax.vmap(per_layer)(params["dec"])  # ([L,B,T,Hkv,Dh], [L,...])


def decode_step(cfg: ModelConfig, params, tokens, cache, pos, ctx: ShardCtx | None = None):
    """One decoder token against cached memory K/V. tokens [B,1]."""
    x = embed_tokens(params["embed"], tokens)
    pos_emb = jax.lax.dynamic_slice_in_dim(
        params["pos_dec"], jnp.clip(pos, 0, cfg.max_target_len - 1), 1, axis=0)
    x = x + pos_emb.astype(CDTYPE)[None]

    mk, mv = cache["mem_kv"]

    def body(h, scanned):
        layer_p, ck, cv, lmk, lmv = scanned
        a = apply_norm(cfg, layer_p["norm1"], h)
        out, ck, cv = attn.decode_attention(cfg, layer_p["attn"], a, ck, cv, pos)
        h = h + out
        a = apply_norm(cfg, layer_p["norm2"], h)
        h = h + attn.cross_attention(cfg, layer_p["xattn"], a, (lmk, lmv))
        a = apply_norm(cfg, layer_p["norm3"], h)
        h = h + apply_mlp(cfg, layer_p["mlp"], a)
        return h, (ck, cv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec"], cache["self"]["k"], cache["self"]["v"], mk, mv))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], x)
    new_cache = {"self": {"k": nk, "v": nv}, "mem_kv": cache["mem_kv"]}
    return logits, new_cache
