"""Sharding context threaded through the model zoo.

Models are written sharding-agnostic; a ``ShardCtx`` (or None on a single
device) supplies the mesh, axis names and constraint helpers. The MoE layer
uses it to run expert-parallel inside ``shard_map``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: Mesh
    batch_axes: tuple[str, ...] = ("data",)   # ('pod','data') multi-pod
    model_axis: str = "model"
    # hillclimb knobs (see EXPERIMENTS.md §Perf)
    seq_shard_attn: bool = False   # shard long KV over model axis at decode
    zero3: bool = True             # shard weights over batch axes too
    bf16_attn: bool = False        # bf16 QK^T / RoPE (kills f32 bwd traffic)
    remat: str = "full"            # full | dots (save dot outputs)
    weight_mode: str = "fsdp"      # fsdp | tp2d (decode: resident weights)
    cast_params_once: bool = False  # bf16-cast stacked weights BEFORE the
    # layer scan so the per-layer ZeRO all-gather moves bf16, not f32
    attn_seq_shard: bool = False   # shard attention over the QUERY SEQUENCE
    # instead of heads (context parallelism): no head/axis divisibility
    # mismatch, logits sharded on Sq, softmax local -> no logits all-reduce
    use_flash: bool = False        # tiled-softmax Pallas attention (TPU):
    # removes [B,H,S,S] logits from HBM (kernels/flashattn.py)
    slstm_chunk: int = 1           # sLSTM timesteps per scan iteration
    # (amortizes recurrent-weight HBM reads; recurrence stays exact)

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def batch_size(self) -> int:
        out = 1
        for a in self.batch_axes:
            out *= self.mesh.shape[a]
        return out

    def constrain(self, x, *spec):
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, P(*spec)))


def constrain(ctx: ShardCtx | None, x, *spec):
    if ctx is None:
        return x
    return ctx.constrain(x, *spec)


def batch_spec(ctx: ShardCtx | None):
    if ctx is None or not ctx.batch_axes:
        return None
    return tuple(ctx.batch_axes) if len(ctx.batch_axes) > 1 else ctx.batch_axes[0]
