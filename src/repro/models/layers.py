"""Shared building blocks: norms, RoPE, MLPs, embeddings, init helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

PDTYPE = jnp.float32      # parameter dtype (master)
CDTYPE = jnp.bfloat16     # compute dtype


def dense_init(key, shape, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, PDTYPE) * s).astype(PDTYPE)


def rmsnorm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w)).astype(dt)


def layernorm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(dt)


def norm_params(cfg: ModelConfig, key):
    if cfg.norm == "rmsnorm":
        return {"w": jnp.zeros((cfg.d_model,), PDTYPE)}
    return {"w": jnp.ones((cfg.d_model,), PDTYPE), "b": jnp.zeros((cfg.d_model,), PDTYPE)}


def apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["w"])
    return layernorm(x, p["w"], p["b"])


def rope_angles(positions, head_dim: int, theta: float):
    """positions [*] -> (cos, sin) of shape [*, head_dim/2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., S, H, Dh]; cos/sin [..., S, Dh/2] (broadcast over heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def mlp_params(cfg: ModelConfig, key, d_ff: int | None = None):
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "silu":
        return {
            "w_gate": dense_init(ks[0], (D, F)),
            "w_up": dense_init(ks[1], (D, F)),
            "w_down": dense_init(ks[2], (F, D)),
        }
    return {
        "w_up": dense_init(ks[0], (D, F)),
        "b_up": jnp.zeros((F,), PDTYPE),
        "w_down": dense_init(ks[1], (F, D)),
        "b_down": jnp.zeros((D,), PDTYPE),
    }


def apply_mlp(cfg: ModelConfig, p, x):
    if cfg.act == "silu":
        h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * (x @ p["w_up"].astype(x.dtype))
        return h @ p["w_down"].astype(x.dtype)
    h = jax.nn.gelu(x @ p["w_up"].astype(x.dtype) + p["b_up"].astype(x.dtype))
    return h @ p["w_down"].astype(x.dtype) + p["b_down"].astype(x.dtype)


def embed_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 2)
    # 0.02 keeps tied-unembedding logits at O(1): std = sqrt(D) * 0.02
    p = {"tok": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), scale=0.02)}
    if not cfg.tie_embeddings:
        p["out"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size))
    return p


def embed_tokens(p, tokens):
    return p["tok"][tokens].astype(CDTYPE)


def unembed(cfg: ModelConfig, p, x):
    w = p["tok"].T if cfg.tie_embeddings else p["out"]
    return x @ w.astype(x.dtype)


def softmax_xent(logits, labels, mask=None):
    """Mean cross-entropy in f32. logits [..., V], labels [...] int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
