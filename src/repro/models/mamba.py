"""Mamba block in the SSD (state-space dual) chunked form.

HARDWARE ADAPTATION (DESIGN.md §2.3): Jamba uses Mamba-1 selective scan,
whose natural CUDA implementation is a fused recurrent kernel. The TPU-native
equivalent is the matmul-dominant SSD/chunked form (Mamba-2): scalar decay
per head, intra-chunk quadratic attention-like einsums (MXU-friendly) and
inter-chunk state carried via an ASSOCIATIVE scan (log-depth, no while loop
— keeps the layer-stack scan the only `while` in the compiled train step).

Shapes: d_in = expand * d_model, heads H = d_in / P (P = 64), state N.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init

P_HEAD = 64


def mamba_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    d_in = cfg.mamba_expand * cfg.d_model
    H = d_in // P_HEAD
    return d_in, H, cfg.mamba_d_state


def mamba_params(cfg: ModelConfig, key):
    D = cfg.d_model
    d_in, H, N = mamba_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "in_proj": dense_init(ks[0], (D, 2 * d_in)),
        "conv_w": dense_init(ks[1], (cfg.mamba_d_conv, d_in), scale=0.5),
        "w_B": dense_init(ks[2], (d_in, N)),
        "w_C": dense_init(ks[3], (d_in, N)),
        "w_dt": dense_init(ks[4], (d_in, H)),
        "b_dt": jnp.full((H,), -2.0, jnp.float32),  # softplus(-2) ~ 0.13
        "A_log": jnp.zeros((H,), jnp.float32),       # a = -exp(A_log) = -1
        "D_skip": jnp.ones((H,), jnp.float32),
        "out_proj": dense_init(ks[5], (d_in, D)),
    }


def _causal_conv(u, w, state=None):
    """Depthwise causal conv over seq. u [B,S,C]; w [K,C].
    With ``state`` [B,K-1,C] (decode), returns (out, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
        ext = jnp.concatenate([pad, u], axis=1)
    else:
        ext = jnp.concatenate([state.astype(u.dtype), u], axis=1)
    out = sum(ext[:, i : i + u.shape[1], :] * w[i].astype(u.dtype) for i in range(K))
    new_state = ext[:, -(K - 1):, :] if K > 1 else None
    return out, new_state


def _ssd_chunked(X, B_, C_, lamb, chunk: int):
    """SSD core. X [B,S,H,P] (already dt-scaled), B_/C_ [B,S,N],
    lamb [B,S,H] log-decay (<=0). Returns y [B,S,H,P]."""
    Bsz, S, H, P = X.shape
    N = B_.shape[-1]
    nc = S // chunk
    Xc = X.reshape(Bsz, nc, chunk, H, P)
    Bc = B_.reshape(Bsz, nc, chunk, N)
    Cc = C_.reshape(Bsz, nc, chunk, N)
    lc = lamb.reshape(Bsz, nc, chunk, H)
    cum = jnp.cumsum(lc.astype(jnp.float32), axis=2)                # [B,nc,c,H]

    # --- intra-chunk (quadratic, MXU) -----------------------------------
    att0 = jnp.einsum("bgin,bgjn->bgij", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    Ldec = cum[:, :, :, None, :] - cum[:, :, None, :, :]            # [B,nc,i,j,H]
    tri = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(Ldec), 0.0)
    y_intra = jnp.einsum("bgij,bgijh,bgjhp->bgihp", att0, L, Xc.astype(jnp.float32))

    # --- inter-chunk state via associative scan -------------------------
    # per chunk: h_out = A_g h_in + S_g with
    #   A_g = exp(cum_last)                       [B,nc,H]
    #   S_g = sum_j exp(cum_last - cum_j) B_j X_j [B,nc,H,N,P]
    dec_out = jnp.exp(cum[:, :, -1:, :] - cum)                       # [B,nc,c,H]
    Sg = jnp.einsum("bgjn,bgjh,bgjhp->bghnp", Bc.astype(jnp.float32), dec_out, Xc.astype(jnp.float32))
    Ag = jnp.exp(cum[:, :, -1, :])                                   # [B,nc,H]

    def combine(a, b):
        A1, S1 = a
        A2, S2 = b
        return A1 * A2, A2[..., None, None] * S1 + S2

    Acum, Scum = jax.lax.associative_scan(combine, (Ag, Sg), axis=1)
    # state BEFORE chunk g = Scum[g-1] (shift right; zero for first chunk)
    h_prev = jnp.concatenate([jnp.zeros_like(Scum[:, :1]), Scum[:, :-1]], axis=1)
    y_inter = jnp.einsum("bgin,bgih,bghnp->bgihp", Cc.astype(jnp.float32), jnp.exp(cum), h_prev)

    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y


def apply_mamba(cfg: ModelConfig, p, x, chunk: int = 128):
    """x [B,S,D] -> [B,S,D] (training/prefill path)."""
    Bsz, S, D = x.shape
    d_in, H, N = mamba_dims(cfg)
    chunk = min(chunk, S)
    assert S % chunk == 0, "seq must be divisible by ssd chunk"

    uz = x @ p["in_proj"].astype(x.dtype)
    u, z = jnp.split(uz, 2, axis=-1)
    u, _ = _causal_conv(u, p["conv_w"])
    u = jax.nn.silu(u)

    B_ = u @ p["w_B"].astype(u.dtype)
    C_ = u @ p["w_C"].astype(u.dtype)
    dt = jax.nn.softplus((u @ p["w_dt"].astype(u.dtype)).astype(jnp.float32) + p["b_dt"])
    a = -jnp.exp(p["A_log"])                                         # [H] < 0
    lamb = dt * a                                                    # [B,S,H]
    X = u.reshape(Bsz, S, H, P_HEAD) * dt[..., None].astype(u.dtype)

    y = _ssd_chunked(X, B_, C_, lamb, chunk)
    y = y + u.reshape(Bsz, S, H, P_HEAD).astype(y.dtype) * p["D_skip"][None, None, :, None]
    y = y.reshape(Bsz, S, d_in).astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"].astype(x.dtype)


def mamba_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_in, H, N = mamba_dims(cfg)
    return {
        "h": jnp.zeros((batch, H, N, P_HEAD), jnp.float32),
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, d_in), dtype),
    }


def decode_mamba(cfg: ModelConfig, p, x, state):
    """One-token decode. x [B,1,D]; returns (y [B,1,D], new state)."""
    Bsz = x.shape[0]
    d_in, H, N = mamba_dims(cfg)
    uz = x @ p["in_proj"].astype(x.dtype)
    u, z = jnp.split(uz, 2, axis=-1)
    u, conv_state = _causal_conv(u, p["conv_w"], state=state["conv"])
    u = jax.nn.silu(u)
    B_ = (u @ p["w_B"].astype(u.dtype)).astype(jnp.float32)[:, 0]     # [B,N]
    C_ = (u @ p["w_C"].astype(u.dtype)).astype(jnp.float32)[:, 0]
    dt = jax.nn.softplus((u @ p["w_dt"].astype(u.dtype)).astype(jnp.float32) + p["b_dt"])[:, 0]  # [B,H]
    a = -jnp.exp(p["A_log"])
    alpha = jnp.exp(dt * a)                                           # [B,H]
    Xt = u.reshape(Bsz, H, P_HEAD).astype(jnp.float32) * dt[..., None]
    h = alpha[..., None, None] * state["h"] + jnp.einsum("bn,bhp->bhnp", B_, Xt)
    y = jnp.einsum("bn,bhnp->bhp", C_, h)
    y = y + u.reshape(Bsz, H, P_HEAD).astype(jnp.float32) * p["D_skip"][None, :, None]
    y = y.reshape(Bsz, 1, d_in).astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, {"h": h, "conv": conv_state}
