"""Deterministic synthetic data pipeline.

Every batch is a pure function of (seed, step, arch) — the property that
makes checkpoint/restart and elastic re-sharding exact: after a restart at
step s the pipeline regenerates precisely the batches s, s+1, ... regardless
of host count (each host materializes only its addressable shard in a real
multi-host deployment; in this single-process container that is the whole
batch).

The stream is a mixture of Zipf-distributed tokens with induced bigram
structure, so small models actually learn (loss decreases) in the examples.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0


def _batch_tokens(cfg: ModelConfig, dc: DataConfig, step: int) -> np.ndarray:
    rng = np.random.default_rng((dc.seed * 1_000_003 + step) & 0xFFFFFFFF)
    B, S = dc.global_batch, dc.seq_len
    V = cfg.vocab_size
    # zipf-ish marginal
    base = rng.zipf(1.5, size=(B, S + 1)).astype(np.int64)
    base = np.clip(base, 1, V - 1)
    # induced structure: with p=0.5, next token = f(prev) (learnable bigram)
    follow = (base[:, :-1] * 2654435761 + 12345) % V
    coin = rng.random((B, S)) < 0.5
    seq = np.where(coin, follow, base[:, 1:])
    seq = np.concatenate([base[:, :1], seq[:, :-1]], axis=1)
    labels = np.where(coin, follow, base[:, 1:])
    return seq.astype(np.int32), labels.astype(np.int32)


def make_batch(cfg: ModelConfig, dc: DataConfig, step: int) -> dict:
    tokens, labels = _batch_tokens(cfg, dc, step)
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    if cfg.frontend == "vision_stub":
        rng = np.random.default_rng(dc.seed * 7 + step)
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((dc.global_batch, cfg.num_patches, cfg.d_model), np.float32) * 0.02,
            jnp.bfloat16)
    if cfg.is_encoder_decoder:
        rng = np.random.default_rng(dc.seed * 13 + step)
        batch["frames"] = jnp.asarray(
            rng.standard_normal((dc.global_batch, dc.seq_len, cfg.d_model), np.float32) * 0.02,
            jnp.bfloat16)
        tgt = min(dc.seq_len, cfg.max_target_len)
        batch["tokens"] = batch["tokens"][:, :tgt]
        batch["labels"] = batch["labels"][:, :tgt]
    return batch


def host_shard(batch: dict, host_id: int, num_hosts: int) -> dict:
    """The slice of the global batch this host feeds (multi-host deployments)."""
    def slc(x):
        per = x.shape[0] // num_hosts
        return x[host_id * per:(host_id + 1) * per]
    return jax.tree.map(slc, batch)
