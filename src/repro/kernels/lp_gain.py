"""Pallas TPU kernel: label-propagation gain computation (refinement hot spot).

The C++ hot loop iterates each vertex's adjacency list and accumulates
per-block connectivity in a sparse map. The TPU-native layout is ELL:
a padded ``[N, DEG]`` neighbour matrix streamed tile-by-tile from HBM into
VMEM. Each program instance handles ``TILE_V`` vertices:

    1. load ``adj/adw`` tiles ``[TILE_V, DEG]``,
    2. gather neighbour block ids from the VMEM-resident ``part`` vector,
    3. one-hot accumulate connectivity ``[TILE_V, K]`` on the VPU
       (K-wide compare+select, no MXU),
    4. emit per-vertex (conn, best alternative block, gain).

Block shapes are (8,128)-aligned: TILE_V = 256, DEG padded to a multiple of
128, K <= 64. VMEM footprint per instance:
256*DEG*(4+4) + 256*K*4 bytes — e.g. DEG=128: ~0.6 MB, well inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_V = 256


def _lp_gain_kernel(adj_ref, adw_ref, part_ref, pt_ref, conn_ref, best_ref, gain_ref, *, k: int):
    N = part_ref.shape[0]
    adj = adj_ref[...]            # [TILE_V, DEG] i32
    adw = adw_ref[...]            # [TILE_V, DEG] f32
    part = part_ref[...]          # [N] i32
    nbr_part = jnp.where(adj < N, part[jnp.clip(adj, 0, N - 1)], k)  # k = "pad"
    conn = jnp.zeros((adj.shape[0], k), jnp.float32)
    # VPU one-hot accumulation: K compare+select passes over the DEG axis
    for b in range(k):
        conn = conn.at[:, b].set(jnp.sum(jnp.where(nbr_part == b, adw, 0.0), axis=1))
    my = pt_ref[...]              # [TILE_V] i32 current blocks of this tile
    row = jax.lax.broadcasted_iota(jnp.int32, (adj.shape[0], k), 1)
    cur = jnp.sum(jnp.where(row == my[:, None], conn, 0.0), axis=1)
    masked = jnp.where(row == my[:, None], -jnp.inf, conn)
    best = jnp.argmax(masked, axis=1).astype(jnp.int32)
    gain = jnp.max(masked, axis=1) - cur
    conn_ref[...] = conn
    best_ref[...] = best
    gain_ref[...] = gain


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def lp_gain_pallas(
    adj: jax.Array,   # [N, DEG] i32 (padded neighbour id == N)
    adw: jax.Array,   # [N, DEG] f32
    part: jax.Array,  # [N] i32
    k: int,
    interpret: bool = True,
):
    """Returns (conn [N,k], best [N], gain [N]) for every vertex."""
    N, DEG = adj.shape
    Np = ((N + TILE_V - 1) // TILE_V) * TILE_V
    padv = Np - N
    adj_p = jnp.pad(adj, ((0, padv), (0, 0)), constant_values=N)
    adw_p = jnp.pad(adw, ((0, padv), (0, 0)))
    part_p = jnp.pad(part, (0, padv))
    grid = (Np // TILE_V,)

    conn, best, gain = pl.pallas_call(
        functools.partial(_lp_gain_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_V, DEG), lambda i: (i, 0)),
            pl.BlockSpec((TILE_V, DEG), lambda i: (i, 0)),
            pl.BlockSpec((N,), lambda i: (0,)),           # full part vector
            pl.BlockSpec((TILE_V,), lambda i: (i,)),      # this tile's blocks
        ],
        out_specs=[
            pl.BlockSpec((TILE_V, k), lambda i: (i, 0)),
            pl.BlockSpec((TILE_V,), lambda i: (i,)),
            pl.BlockSpec((TILE_V,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np, k), jnp.float32),
            jax.ShapeDtypeStruct((Np,), jnp.int32),
            jax.ShapeDtypeStruct((Np,), jnp.float32),
        ],
        interpret=interpret,
    )(adj_p, adw_p, part, part_p)
    return conn[:N], best[:N], gain[:N]
