"""Pallas TPU kernels for the paper's compute hot spots (+ jnp oracles)."""
from .ops import lp_gain, mapcost  # noqa: F401
