"""Jitted public wrappers for the kernels.

``backend`` selection: on TPU the Pallas kernels run compiled; on CPU (this
container) they run in interpret mode for validation, and callers that need
speed (the partitioner inner loops) use the jnp reference implementations,
which XLA:CPU fuses well.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .flashattn import flash_attention_pallas
from .lp_gain import lp_gain_pallas
from .mapcost import mapcost_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def mapcost(rows, cols, ewgt, pe_of, g_below, dvec, use_pallas: bool | None = None):
    """J(C, D, Pi) over directed edge arrays (padding weight must be 0)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return mapcost_pallas(rows, cols, ewgt, pe_of, g_below, dvec,
                              interpret=not _on_tpu())
    return ref.mapcost_ref(rows, cols, ewgt, pe_of, g_below, dvec)


def lp_gain(adj, adw, part, k: int, use_pallas: bool | None = None):
    """(conn, best, gain) for balanced LP refinement over an ELL adjacency."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return lp_gain_pallas(adj, adw, part, k, interpret=not _on_tpu())
    return ref.lp_gain_ref(adj, adw, part, k)


def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    use_pallas: bool | None = None):
    """Tiled-softmax SDPA. q [B,S,H,D], k/v [B,S,Hkv,D] (GQA expanded here).

    On TPU this is the fix for the prefill/train memory roofline term:
    no [B,H,S,S] logits ever touch HBM (see kernels/flashattn.py)."""
    B, S, H, D = q.shape
    rep = H // k.shape[2]
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    flat = lambda x: jnp.swapaxes(x, 1, 2).reshape(B * H, S, D)
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        o = flash_attention_pallas(flat(q), flat(k), flat(v), causal, window,
                                   interpret=not _on_tpu())
    else:
        o = ref.flash_ref(flat(q), flat(k), flat(v), causal, window)
    return jnp.swapaxes(o.reshape(B, H, S, D), 1, 2)
