"""Jitted public wrappers for the kernels — the single dispatch point.

Every caller that wants a kernel (refinement gain pass, mapping-cost
evaluation, attention) goes through this module; nothing else in the repo
decides pallas-vs-XLA on its own. The policy lives in one helper:

``kernel_backend()`` returns one of

* ``"pallas"``    — a real TPU backend is present: Pallas kernels run
                    COMPILED (``interpret=False``).
* ``"interpret"`` — forced via ``REPRO_KERNEL_BACKEND=interpret``: Pallas
                    kernels run under the interpreter (CI parity testing on
                    CPU; slow).
* ``"xla"``       — anything else (CPU/GPU default): the pure-jnp reference
                    implementations, which XLA fuses well.

``REPRO_KERNEL_BACKEND`` overrides the device-derived default with any of
the three values; per-call ``use_pallas=`` arguments override both.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from . import ref
from .coarsen_kernels import contract_edges_pallas, hem_propose_pallas
from .flashattn import flash_attention_pallas
from .lp_gain import lp_gain_pallas
from .mapcost import mapcost_pallas
from .split import gather_rows_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def kernel_backend() -> str:
    """Resolve the kernel dispatch policy (see module docstring)."""
    forced = os.environ.get("REPRO_KERNEL_BACKEND", "").lower()
    if forced in ("pallas", "interpret", "xla"):
        return forced
    return "pallas" if _on_tpu() else "xla"


def dispatch(use_pallas: bool | None = None) -> tuple[bool, bool]:
    """(use_pallas, interpret) for a kernel call.

    ``use_pallas=None`` defers to :func:`kernel_backend`; an explicit bool
    keeps the old per-call override semantics (interpret mode is then
    enabled exactly when no real TPU is present).
    """
    if use_pallas is None:
        backend = kernel_backend()
        return backend != "xla", backend == "interpret"
    return use_pallas, not _on_tpu()


_mapcost_ref_jit = jax.jit(ref.mapcost_ref)


def mapcost(rows, cols, ewgt, pe_of, g_below, dvec, use_pallas: bool | None = None):
    """J(C, D, Pi) over directed edge arrays (padding weight must be 0)."""
    use_pallas, interpret = dispatch(use_pallas)
    if use_pallas:
        return mapcost_pallas(rows, cols, ewgt, pe_of, g_below, dvec,
                              interpret=interpret)
    return _mapcost_ref_jit(rows, cols, ewgt, pe_of, g_below, dvec)


def lp_gain(adj, adw, part, k: int, use_pallas: bool | None = None):
    """(conn, best, gain) for balanced LP refinement over an ELL adjacency."""
    use_pallas, interpret = dispatch(use_pallas)
    if use_pallas:
        return lp_gain_pallas(adj, adw, part, k, interpret=interpret)
    return ref.lp_gain_ref(adj, adw, part, k)


def gather_rows(src, idx, use_pallas: bool | None = None):
    """Masked-compaction gather for the split op: out[b,j] = src[idx[b,j]].

    ``idx`` is clipped in-range inside both implementations; pure data
    movement, so pallas/interpret/xla agree BITWISE (the device-resident
    multisection's determinism depends on this; tested in test_kernels).
    """
    use_pallas, interpret = dispatch(use_pallas)
    if use_pallas:
        return gather_rows_pallas(src, idx, interpret=interpret)
    return ref.gather_rows_ref(src, idx)


def hem_propose(adj, adw, jit, matched, use_pallas: bool | None = None):
    """Per-row HEM proposal scan over the [N, DEG] ELL adjacency.

    ``matched`` is the [N] 0/1 i32 matched vector; returns [N] i32
    proposals (N = no proposal). Score math is elementwise f32 and the
    only reductions are max/min, so pallas/interpret/xla agree BITWISE
    (the coarsening cascade's determinism depends on this; tested in
    test_coarsen_kernels).
    """
    use_pallas, interpret = dispatch(use_pallas)
    if use_pallas:
        return hem_propose_pallas(adj, adw, jit, matched, interpret=interpret)
    return ref.hem_propose_ref(adj, adw, jit, matched)


def contract_edges(cand, candw, use_pallas: bool | None = None):
    """Row-local merge/dedup/accumulate for contraction.

    ``cand [N, D2]`` holds the coarse-mapped neighbour candidates of each
    coarse row's fine members (sentinel N = invalid, weight 0). Returns
    ``(nbr, w, cnt)``; weight totals use a fixed add chain, so backends
    agree BITWISE (see kernels/ref.py:merge_dedup_rows).
    """
    use_pallas, interpret = dispatch(use_pallas)
    if use_pallas:
        return contract_edges_pallas(cand, candw, interpret=interpret)
    return ref.contract_edges_ref(cand, candw, cand.shape[0])


def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    use_pallas: bool | None = None):
    """Tiled-softmax SDPA. q [B,S,H,D], k/v [B,S,Hkv,D] (GQA expanded here).

    On TPU this is the fix for the prefill/train memory roofline term:
    no [B,H,S,S] logits ever touch HBM (see kernels/flashattn.py)."""
    B, S, H, D = q.shape
    rep = H // k.shape[2]
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    flat = lambda x: jnp.swapaxes(x, 1, 2).reshape(B * H, S, D)
    use_pallas, interpret = dispatch(use_pallas)
    if use_pallas:
        o = flash_attention_pallas(flat(q), flat(k), flat(v), causal, window,
                                   interpret=interpret)
    else:
        o = ref.flash_ref(flat(q), flat(k), flat(v), causal, window)
    return jnp.swapaxes(o.reshape(B, H, S, D), 1, 2)
