"""Pallas TPU kernel: J(C,D,Pi) communication-cost evaluation.

Edge-parallel: the grid tiles the directed edge arrays; each program
instance streams a ``(TILE_E,)`` slice of (rows, cols, ewgt) from HBM into
VMEM, gathers both endpoint PE ids from the (VMEM-resident) assignment
vector, computes the hierarchy distance with the mixed-radix bit-label
trick entirely in registers, and writes a per-tile partial sum. The final
reduction over tiles happens in the caller.

TPU adaptation notes:
* The hot operation in the C++ code is a scalar hash-table / array gather
  per edge; here the per-edge distance is a dense [TILE_E, l] integer-divide
  + compare + popcount-style reduction on the VPU — no MXU involvement.
* ``pe_of`` (and the tiny ``g_below``/``dvec`` tables) are small enough for
  VMEM (4 B x N; N <= 2^20 fits comfortably), so each edge tile performs
  two vector gathers against VMEM instead of HBM random access — the TPU
  analogue of the paper's O(1) bit-label distance queries.
* TILE_E is a multiple of 8*128 to match VREG lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_E = 2048  # 2 * (8, 128) VREG tiles worth of edges


def _mapcost_kernel(rows_ref, cols_ref, ewgt_ref, pe_ref, gb_ref, dv_ref, out_ref):
    rows = rows_ref[...]
    cols = cols_ref[...]
    w = ewgt_ref[...]
    pe = pe_ref[...]
    pu = pe[rows]
    pv = pe[cols]
    l = gb_ref.shape[0]
    lvl = jnp.zeros(rows.shape, jnp.int32)
    d = jnp.zeros(rows.shape, jnp.float32)
    # l is tiny (2..4): unrolled compare/select chain per level
    for i in range(l):
        gb = gb_ref[i]
        differs = (pu // gb) != (pv // gb)
        lvl = lvl + differs.astype(jnp.int32)
    for i in range(l):
        d = jnp.where(lvl == i + 1, dv_ref[i], d)
    out_ref[0] = jnp.sum(w * d)


@functools.partial(jax.jit, static_argnames=("interpret",))
def mapcost_pallas(
    rows: jax.Array,
    cols: jax.Array,
    ewgt: jax.Array,
    pe_of: jax.Array,
    g_below: jax.Array,
    dvec: jax.Array,
    interpret: bool = True,
) -> jax.Array:
    """J(C,D,Pi) via the Pallas kernel. Pads the edge arrays to TILE_E."""
    M = rows.shape[0]
    Mp = ((M + TILE_E - 1) // TILE_E) * TILE_E
    pad = Mp - M
    N = pe_of.shape[0]
    rows = jnp.pad(rows, (0, pad))
    cols = jnp.pad(cols, (0, pad))
    ewgt = jnp.pad(ewgt, (0, pad))
    grid = (Mp // TILE_E,)

    partial = pl.pallas_call(
        _mapcost_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_E,), lambda i: (i,)),
            pl.BlockSpec((TILE_E,), lambda i: (i,)),
            pl.BlockSpec((TILE_E,), lambda i: (i,)),
            pl.BlockSpec((N,), lambda i: (0,)),           # pe_of: whole vector in VMEM
            pl.BlockSpec((g_below.shape[0],), lambda i: (0,)),
            pl.BlockSpec((dvec.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((grid[0],), jnp.float32),
        interpret=interpret,
    )(rows, cols, ewgt, pe_of, g_below, dvec)
    return jnp.sum(partial) / 2.0
