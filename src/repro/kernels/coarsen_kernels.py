"""Pallas TPU kernels for coarsening: HEM proposals + contraction merge.

Coarsening is the last multilevel stage without a kernel path: the seed's
``coarsen.hem_match`` runs two ``segment_max``/``segment_min`` scatter
passes per round over the ``[M]`` edge arrays, and ``coarsen.contract``
two stable argsorts. The TPU-native restatement works row-wise over the
padded ``[N, DEG]`` ELL adjacency (same layout as ``lp_gain``):

``hem_propose`` — one program instance scans ``TILE_V`` rows: load the
    ``adj/adw/jit`` tiles, gather matched flags from the VMEM-resident
    ``matched`` vector, take the per-row max jittered score and the
    smallest-id tie-break. Replaces both segment passes with a single
    streaming pass, no scatters.

``contract_edges`` — one program instance merges ``TILE_C`` coarse rows:
    each row holds the ``2*DEG`` coarse-mapped neighbour candidates of its
    (<= 2) fine members; a fixed-order compare/accumulate chain dedups ids
    and sums weights. Fully tiled — NO resident vectors — so it scales
    with HBM, not VMEM.

Both kernel bodies execute the SAME jnp code as the oracles
(kernels/ref.py: ``hem_row_scan`` / ``merge_dedup_rows``), so pallas /
interpret / xla agree BITWISE: score math is elementwise f32, reductions
are max/min/int-only, and weight totals are a fixed add chain XLA never
reassociates. The device-resident multisection's shadow-verification twin
(PR 8) depends on this; tested in tests/test_coarsen_kernels.py.

VMEM budget per instance: hem_propose holds ``matched [Np] i32`` resident
(+3 row tiles), so Np <~ 3M rows on a 16 MB core; contract_edges holds
only its tiles (~``TILE_C * 2*DEG * 4 * 4`` bytes). See DESIGN.md §13.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE_V = 256   # hem_propose rows per program instance (see lp_gain.TILE_V)
TILE_C = 256   # contract_edges rows per program instance


def _hem_propose_kernel(adj_ref, adw_ref, jit_ref, matched_ref, prop_ref,
                        *, n_ids: int):
    i = pl.program_id(0)
    adj = adj_ref[...]            # [TILE_V, DEG] i32
    adw = adw_ref[...]            # [TILE_V, DEG] f32
    jit = jit_ref[...]            # [TILE_V, DEG] f32
    matched = matched_ref[...]    # [Np] i32 (resident; padded rows = 1)
    T = adj.shape[0]
    u = i * T + jax.lax.broadcasted_iota(jnp.int32, (T, 1), 0)[:, 0]
    prop_ref[...] = ref.hem_row_scan(adj, adw, jit, matched, u, n_ids)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hem_propose_pallas(adj: jax.Array, adw: jax.Array, jit: jax.Array,
                       matched: jax.Array, interpret: bool = True) -> jax.Array:
    """Per-row HEM proposal over ELL adjacency. Returns [N] i32 (N = none).

    ``matched`` is the [N] 0/1 i32 matched vector; padding rows must be
    matched (the wrapper pads with 1 so tile-pad rows propose nothing).
    """
    N, DEG = adj.shape
    Np = ((N + TILE_V - 1) // TILE_V) * TILE_V
    padv = Np - N
    adj_p = jnp.pad(adj, ((0, padv), (0, 0)), constant_values=N)
    adw_p = jnp.pad(adw, ((0, padv), (0, 0)))
    jit_p = jnp.pad(jit, ((0, padv), (0, 0)))
    mat_p = jnp.pad(matched, (0, padv), constant_values=1)
    prop = pl.pallas_call(
        functools.partial(_hem_propose_kernel, n_ids=N),
        grid=(Np // TILE_V,),
        in_specs=[
            pl.BlockSpec((TILE_V, DEG), lambda i: (i, 0)),
            pl.BlockSpec((TILE_V, DEG), lambda i: (i, 0)),
            pl.BlockSpec((TILE_V, DEG), lambda i: (i, 0)),
            pl.BlockSpec((Np,), lambda i: (0,)),          # matched resident
        ],
        out_specs=pl.BlockSpec((TILE_V,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), jnp.int32),
        interpret=interpret,
    )(adj_p, adw_p, jit_p, mat_p)
    return prop[:N]


def _contract_edges_kernel(cand_ref, candw_ref, nbr_ref, w_ref, cnt_ref,
                           *, sent: int):
    nbr, w, cnt = ref.merge_dedup_rows(cand_ref[...], candw_ref[...], sent)
    nbr_ref[...] = nbr
    w_ref[...] = w
    cnt_ref[...] = cnt


@functools.partial(jax.jit, static_argnames=("interpret",))
def contract_edges_pallas(cand: jax.Array, candw: jax.Array,
                          interpret: bool = True):
    """Row-local merge/dedup/accumulate for contraction.

    ``cand [N, D2]`` holds coarse neighbour ids (sentinel ``N`` = invalid,
    weight 0). Returns ``(nbr [N, D2], w [N, D2], cnt [N])`` — see
    ref.merge_dedup_rows. Fully tiled: no resident vectors.
    """
    N, D2 = cand.shape
    Np = ((N + TILE_C - 1) // TILE_C) * TILE_C
    padv = Np - N
    cand_p = jnp.pad(cand, ((0, padv), (0, 0)), constant_values=N)
    candw_p = jnp.pad(candw, ((0, padv), (0, 0)))
    nbr, w, cnt = pl.pallas_call(
        functools.partial(_contract_edges_kernel, sent=N),
        grid=(Np // TILE_C,),
        in_specs=[
            pl.BlockSpec((TILE_C, D2), lambda i: (i, 0)),
            pl.BlockSpec((TILE_C, D2), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_C, D2), lambda i: (i, 0)),
            pl.BlockSpec((TILE_C, D2), lambda i: (i, 0)),
            pl.BlockSpec((TILE_C,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np, D2), jnp.int32),
            jax.ShapeDtypeStruct((Np, D2), candw.dtype),
            jax.ShapeDtypeStruct((Np,), jnp.int32),
        ],
        interpret=interpret,
    )(cand_p, candw_p)
    return nbr[:N], w[:N], cnt[:N]
