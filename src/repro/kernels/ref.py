"""Pure-jnp oracles for the Pallas kernels (the correctness references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mapcost_ref(
    rows: jax.Array,      # [M] i32
    cols: jax.Array,      # [M] i32
    ewgt: jax.Array,      # [M] f32 (0 on padding)
    pe_of: jax.Array,     # [N] i32
    g_below: jax.Array,   # [l] i32 group sizes below each level (1, a1, a1a2, ..)
    dvec: jax.Array,      # [l] f32 distances
) -> jax.Array:
    """J(C,D,Pi): sum over directed edges of w * dist(pe_u, pe_v), halved."""
    pu = pe_of[rows]
    pv = pe_of[cols]
    diff = (pu[:, None] // g_below[None, :]) != (pv[:, None] // g_below[None, :])
    lvl = jnp.sum(diff.astype(jnp.int32), axis=-1)
    safe = jnp.clip(lvl - 1, 0, dvec.shape[0] - 1)
    d = jnp.where(lvl > 0, dvec[safe], 0.0)
    return jnp.sum(ewgt * d) / 2.0


def lp_gain_ref(
    adj: jax.Array,       # [N, DEG] i32 padded neighbour ids (N = self/pad)
    adw: jax.Array,       # [N, DEG] f32 edge weights (0 on padding)
    part: jax.Array,      # [N] i32 current block of each vertex
    k: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-vertex block connectivity, best alternative block and its gain.

    Returns (conn [N,k], best [N], gain [N]).
    """
    N = adj.shape[0]
    nbr_part = jnp.where(adj < N, part[jnp.clip(adj, 0, N - 1)], 0)
    onehot = jax.nn.one_hot(nbr_part, k, dtype=adw.dtype)  # [N, DEG, k]
    conn = jnp.einsum("nd,ndk->nk", adw, onehot)
    cur = jnp.take_along_axis(conn, part[:, None], axis=1)[:, 0]
    masked = jnp.where(jax.nn.one_hot(part, k, dtype=bool), -jnp.inf, conn)
    best = jnp.argmax(masked, axis=1).astype(jnp.int32)
    gain = jnp.max(masked, axis=1) - cur
    return conn, best, gain


def gather_rows_ref(src: jax.Array, idx: jax.Array) -> jax.Array:
    """[k, L] gather of a 1-D source: out[b, j] = src[idx[b, j]].

    ``idx`` must be in-range (callers clip); exact for every dtype — the
    device-resident split op relies on bitwise parity between this oracle
    and the Pallas kernel (no float math anywhere).
    """
    return jnp.take(src, jnp.clip(idx, 0, src.shape[0] - 1))


def csr_to_ell(rows, cols, ewgt, N: int, DEG: int):
    """Convert directed CSR edge arrays to padded ELL [N, DEG] (jnp).

    Edges beyond DEG per row are dropped (callers choose DEG >= max degree).
    Padding slots hold neighbour id N and weight 0.
    """
    order = jnp.argsort(rows, stable=True)
    r, c, w = rows[order], cols[order], ewgt[order]
    # position of each edge within its (sorted) row
    M = r.shape[0]
    rc = jnp.clip(r, 0, N - 1)
    counts = jax.ops.segment_sum(jnp.ones((M,), jnp.int32), rc, num_segments=N)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(M, dtype=jnp.int32) - starts[rc]
    slot = rc * DEG + pos
    valid = (pos < DEG) & (r < N)
    slot = jnp.where(valid, slot, N * DEG)
    adj = jnp.full((N * DEG + 1,), N, jnp.int32).at[slot].set(c, mode="drop")[:-1]
    adw = jnp.zeros((N * DEG + 1,), w.dtype).at[slot].set(jnp.where(valid, w, 0.0), mode="drop")[:-1]
    return adj.reshape(N, DEG), adw.reshape(N, DEG)


def hem_row_scan(adj, adw, jit, matched, u, n_ids: int):
    """Shared per-row heaviest-free-neighbour scan (HEM proposal step).

    ``adj``/``adw``/``jit`` are ``[T, DEG]`` row tiles of the padded ELL
    adjacency (neighbour id ``n_ids`` = padding), ``matched`` a 0/1 i32
    matched vector covering at least ``n_ids`` entries (the Pallas wrapper
    pads it to a tile multiple, hence the explicit sentinel), ``u`` the
    ``[T]`` global row ids of the tile. Returns the ``[T]`` i32 proposal
    per row (``n_ids`` = no proposal).

    This body is executed verbatim by BOTH the Pallas kernel
    (kernels/coarsen_kernels.py, on VMEM tiles) and the jnp oracle
    (:func:`hem_propose_ref`, on the full array) — one source of truth, so
    the backends agree bitwise: the score is elementwise f32, the only
    reductions are max/min (rounding-free), the gathers pure data
    movement.
    """
    Nm = matched.shape[0]
    nbr_matched = matched[jnp.clip(adj, 0, Nm - 1)]
    own_matched = matched[jnp.clip(u, 0, Nm - 1)]
    valid = ((adj < n_ids) & (adj != u[:, None])
             & (own_matched[:, None] == 0) & (nbr_matched == 0))
    jj = jit * 1e-3
    score = jnp.where(valid, adw * (1.0 + jj) + jj, -jnp.inf)
    best = jnp.max(score, axis=1)                       # order-free (max)
    has = best > -jnp.inf
    # tie-break: smallest neighbour id among best-scoring free edges
    cand = jnp.where(valid & (score == best[:, None]), adj, n_ids)
    prop = jnp.min(cand, axis=1)
    return jnp.where(has, prop, n_ids).astype(jnp.int32)


def hem_propose_ref(adj, adw, jit, matched):
    """jnp oracle for the hem_propose kernel: full-array row scan."""
    u = jnp.arange(adj.shape[0], dtype=jnp.int32)
    return hem_row_scan(adj, adw, jit, matched, u, adj.shape[0])


def merge_dedup_rows(cand, candw, sent: int):
    """Shared per-row merge/dedup/accumulate (contraction step).

    ``cand [T, D2]`` holds coarse neighbour ids (``sent`` = invalid slot,
    weight 0 there); returns ``(nbr [T, D2], w [T, D2], cnt [T])`` where
    ``nbr`` keeps each distinct id at its FIRST slot (others ``sent``),
    ``w`` the per-id weight total, ``cnt`` the distinct count per row.

    Weight totals are accumulated as a FIXED chain of ``D2`` adds in slot
    order — XLA never reassociates distinct f32 adds, so the Pallas
    kernel (tiles) and the jnp oracle (full array) agree bitwise; the
    first-occurrence and count passes are integer-only (order-free).
    """
    D2 = cand.shape[-1]
    acc = jnp.zeros_like(candw)
    for i in range(D2):
        acc = acc + jnp.where(cand == cand[:, i:i + 1], candw[:, i:i + 1], 0.0)
    firstpos = jnp.full(cand.shape, D2, jnp.int32)
    for i in range(D2 - 1, -1, -1):
        firstpos = jnp.where(cand == cand[:, i:i + 1], i, firstpos)
    colid = jax.lax.broadcasted_iota(jnp.int32, cand.shape, 1)
    is_first = (firstpos == colid) & (cand != sent)
    nbr = jnp.where(is_first, cand, sent).astype(jnp.int32)
    w = jnp.where(is_first, acc, 0.0)
    cnt = jnp.sum(is_first.astype(jnp.int32), axis=1)
    return nbr, w, cnt


def contract_edges_ref(cand, candw, sent: int):
    """jnp oracle for the contract_edges kernel."""
    return merge_dedup_rows(cand, candw, sent)


def flash_ref(q, k, v, causal: bool = True, window: int = 0):
    """Oracle SDPA for the flash kernel. q/k/v [BH, S, D] -> [BH, S, D]."""
    S = q.shape[1]
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= rows - cols < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
