"""Pallas gather kernel backing the device-resident induced-subgraph split.

The split op (core/graph.py:split_blocks) is a stable-sort-by-block
compaction: after host-free bookkeeping (segment offsets, relabel) every
child array is produced by one *masked row gather* from a flat source
vector — ``out[b, j] = src[idx[b, j]]`` with the mask applied outside.
That gather is the only memory-bound inner loop, so it is the piece worth
a kernel: ``src`` stays VMEM-resident across the row grid while the
``[1, TILE_L]`` index tiles stream from HBM (same shape discipline as
``lp_gain``'s neighbour gather).

Pure data movement — no float arithmetic — so the compiled kernel, the
interpreter, and the jnp oracle (kernels/ref.py:gather_rows_ref) are
bitwise identical; backend choice can never perturb a mapping.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_L = 512  # index-tile width (lane-dim aligned; see lp_gain.TILE_V)


def _gather_rows_kernel(src_ref, idx_ref, out_ref):
    src = src_ref[...]
    idx = idx_ref[...]
    out_ref[...] = jnp.take(src, jnp.clip(idx, 0, src.shape[0] - 1))


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows_pallas(src: jax.Array, idx: jax.Array,
                       interpret: bool = True) -> jax.Array:
    """out[b, j] = src[clip(idx[b, j])] for 1-D ``src`` and [k, L] ``idx``."""
    K, L = idx.shape
    S = src.shape[0]
    Lp = ((L + TILE_L - 1) // TILE_L) * TILE_L
    if Lp != L:
        idx = jnp.pad(idx, ((0, 0), (0, Lp - L)))
    out = pl.pallas_call(
        _gather_rows_kernel,
        grid=(K, Lp // TILE_L),
        in_specs=[
            pl.BlockSpec((S,), lambda i, j: (0,)),        # src resident
            pl.BlockSpec((1, TILE_L), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, TILE_L), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((K, Lp), src.dtype),
        interpret=interpret,
    )(src, idx)
    return out[:, :L]
