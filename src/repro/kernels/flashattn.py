"""Pallas TPU kernel: flash attention (tiled online-softmax SDPA).

Why it's here (§Roofline): every prefill/train cell's memory term is
dominated by materializing [B,H,Sq,Sk] logits/probs in HBM. Flash keeps the
whole softmax in VMEM: HBM traffic collapses to Q/K/V/O streaming —
per (q-tile, k-tile) pass nothing but the inputs moves.

Layout: inputs [BH, S, D] (heads pre-flattened, GQA pre-expanded by ops.py).
Grid (BH, Sq/BQ, Sk/BK); the innermost grid dim is "arbitrary" (sequential)
so VMEM scratch (running max m, normalizer l, accumulator acc) carries
across k-tiles — the standard TPU flash pattern. Tile sizes are multiples
of (8, 128): BQ=128, BK=128, D padded to 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30
BQ = 128
BK = 128

# renamed TPUCompilerParams -> CompilerParams across pallas releases
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool, nk: int, window: int,
                  s_real: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                       # [BQ, D]
    k = k_ref[0].astype(jnp.float32)                       # [BK, D]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [BQ, BK]

    rows = iq * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)
    cols = ik * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
    mask = cols < s_real  # padded key columns never win the softmax
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= rows - cols < window
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[...]                                    # [BQ]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)                        # [BQ]
    p = jnp.exp(s - m_new[:, None])                        # [BQ, BK]
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1)
    acc_scr[...] = alpha[:, None] * acc_scr[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def flash_attention_pallas(q, k, v, causal: bool = True, window: int = 0,
                           interpret: bool = True):
    """q/k/v [BH, S, D] -> o [BH, S, D]. D and S padded to tile multiples."""
    BH, S, D = q.shape
    scale = D ** -0.5
    Sp = ((S + BQ - 1) // BQ) * BQ
    Dp = ((D + 127) // 128) * 128
    pad = lambda x: jnp.pad(x, ((0, 0), (0, Sp - S), (0, Dp - D)))
    qp, kp, vp = pad(q), pad(k), pad(v)
    # padded k rows must never win the softmax: handled by the causal/window
    # masks for in-range rows; for pure bidirectional pads, mask via column
    # index >= S:
    nq, nk = Sp // BQ, Sp // BK

    kern = functools.partial(_flash_kernel, scale=scale, causal=causal,
                             nk=nk, window=window, s_real=S)
    out = pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, BQ, Dp), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, BK, Dp), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, BK, Dp), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, BQ, Dp), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sp, Dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BQ,), jnp.float32),
            pltpu.VMEM((BQ,), jnp.float32),
            pltpu.VMEM((BQ, Dp), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :S, :D]
