"""AdamW with global-norm clipping (pure JAX; states shard like params)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree))
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads, opt_state: OptState, params):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = _schedule(cfg, step.astype(jnp.float32))
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        new_p = p.astype(jnp.float32) - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                              + cfg.weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    flat = jax.tree.map(upd, params, grads, opt_state.mu, opt_state.nu)
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step, new_mu, new_nu), {"grad_norm": gnorm, "lr": lr}
