"""The jitted train step: loss -> grads -> AdamW, mixed precision."""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.sharding import ShardCtx
from .optimizer import AdamWConfig, OptState, adamw_update, init_opt_state


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def init_train_state(cfg: ModelConfig, key, V: int = 1) -> TrainState:
    params = M.init_fn(cfg, key, V=V)
    return TrainState(params=params, opt=init_opt_state(params))


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    ctx: ShardCtx | None = None):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state: TrainState, batch):
        def loss_of(p):
            return M.loss_fn(cfg, p, batch, ctx)

        loss, grads = jax.value_and_grad(loss_of)(state.params)
        params, opt, metrics = adamw_update(opt_cfg, grads, state.opt, state.params)
        metrics = {"loss": loss, **metrics}
        return TrainState(params, opt), metrics

    return train_step


def make_eval_step(cfg: ModelConfig, ctx: ShardCtx | None = None):
    def eval_step(params, batch):
        return M.loss_fn(cfg, params, batch, ctx)
    return eval_step
