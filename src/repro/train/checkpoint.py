"""Sharded checkpointing with mesh-shape-agnostic restore (elastic restart).

Format: one ``.npz`` per save (flattened key paths) + a msgpack manifest
with step/config. Saves run on a background thread (training continues);
restore re-places arrays under whatever mesh/sharding the *new* job uses,
which is what makes elastic re-scaling (e.g. 2 pods -> 1 pod after a pod
loss) a restart rather than an outage.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_like(template, flat: dict[str, np.ndarray]):
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in leaves_p:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs model {leaf.shape}")
        out.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    def _paths(self, step: int) -> tuple[str, str]:
        return (os.path.join(self.dir, f"ckpt_{step:08d}.npz"),
                os.path.join(self.dir, f"ckpt_{step:08d}.manifest"))

    def save(self, step: int, state: dict[str, Any], meta: dict | None = None,
             blocking: bool = False):
        flat = {}
        for name, tree in state.items():
            for k, v in _flatten(tree).items():
                flat[f"{name}::{k}"] = v

        def _write():
            npz_path, man_path = self._paths(step)
            tmp = npz_path + ".tmp.npz"
            np.savez(tmp, **flat)
            os.replace(tmp, npz_path)
            with open(man_path, "wb") as f:
                f.write(msgpack.packb({"step": step, "time": time.time(),
                                       "keys": sorted(flat.keys()), **(meta or {})}))
            self._gc()

        if blocking:
            _write()
        else:
            self.wait()
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            for p in self._paths(s):
                try:
                    os.remove(p)
                except OSError:
                    pass

    def all_steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.dir):
            if f.startswith("ckpt_") and f.endswith(".manifest"):
                out.append(int(f[5:13]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, templates: dict[str, Any],
                shardings: dict[str, Any] | None = None) -> dict[str, Any]:
        """Restore under NEW shardings (elastic restart). ``templates`` give
        tree structure/shapes; ``shardings`` optionally re-place on a mesh."""
        npz_path, _ = self._paths(step)
        data = np.load(npz_path)
        out = {}
        for name, template in templates.items():
            flat = {k.split("::", 1)[1]: data[k] for k in data.files
                    if k.startswith(f"{name}::")}
            tree = _unflatten_like(template, flat)
            if shardings and name in shardings and shardings[name] is not None:
                tree = jax.tree.map(
                    lambda a, s: jax.device_put(a, s), tree, shardings[name])
            out[name] = tree
        return out
