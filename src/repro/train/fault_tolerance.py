"""Fault tolerance: restartable training with failure injection.

At 1000+ node scale the assumptions are:
* node failures are ROUTINE (MTBF of a 512-chip job ~ hours), so recovery
  must be checkpoint-restart with a bounded work loss window;
* the data pipeline is a pure function of step (data/pipeline.py), so a
  restart replays the exact token stream — bitwise-identical recovery
  modulo collective reduction order;
* elastic restarts re-place the same checkpoint under a different mesh
  (launch/train.py --mesh), e.g. dropping from 2 pods to 1 after a pod
  loss — checkpoint/restore is mesh-shape-agnostic by design;
* stragglers: (a) inside the mapping engine, the paper's own scheduling
  strategies (§4) keep lanes busy; (b) for the training loop we implement
  step-time watchdogs that flag slow steps and a documented skip-ahead
  policy (re-shard around a straggling host at the next checkpoint
  boundary).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro.faults import FaultInjector, InjectedFault


class InjectedFailure(InjectedFault):
    """Raised by FailureInjector to simulate a node loss.

    Subclasses the shared :class:`repro.faults.InjectedFault` so generic
    fault-handling code (e.g. the mapping service's retry classifier) can
    treat trainer failures uniformly; kept as its own name because the
    restart loop and launch/train.py catch it specifically.
    """


@dataclasses.dataclass
class FailureInjector:
    """Deterministically fail at given steps (tests/examples).

    Thin step-indexed front over :class:`repro.faults.FaultInjector`: the
    trainer's "fail at step s, once" semantics are the ``fail_at`` mode of
    the shared injector with the step passed as the explicit index.
    """

    fail_at_steps: tuple[int, ...] = ()
    fired: set = dataclasses.field(default_factory=set)

    def __post_init__(self):
        self._inj = FaultInjector(fail_at={"train_step": self.fail_at_steps},
                                  error_type=InjectedFailure)

    def check(self, step: int):
        try:
            self._inj.check("train_step", index=step)
        except InjectedFailure:
            self.fired.add(step)
            raise


@dataclasses.dataclass
class StepWatchdog:
    """Flags straggler steps (> factor x trailing median)."""

    factor: float = 3.0
    window: int = 32
    times: list = dataclasses.field(default_factory=list)
    straggler_steps: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        hist = self.times[-self.window:]
        med = sorted(hist)[len(hist) // 2]
        slow = len(hist) >= 8 and seconds > self.factor * med
        if slow:
            self.straggler_steps.append(step)
        return slow


def run_with_restarts(
    run_fn: Callable[[int], int],
    max_restarts: int = 3,
    on_restart: Callable[[int, Exception], None] | None = None,
) -> int:
    """Drive ``run_fn(start_step) -> last_step`` through failures.

    ``run_fn`` must resume from the latest checkpoint when re-invoked; this
    wrapper is the single-process stand-in for a cluster controller."""
    restarts = 0
    start_step = 0
    while True:
        try:
            return run_fn(start_step)
        except InjectedFailure as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart:
                on_restart(restarts, e)
            time.sleep(0.01)  # "reschedule"
            start_step = -1   # sentinel: resume from latest checkpoint
