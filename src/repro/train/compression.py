"""Gradient compression: int8 error-feedback quantization for slow links.

At 1000+ node scale, the pod axis rides DCN (~100x slower than ICI), so the
pod-axis gradient all-reduce is the first collective to compress. We use
per-tensor-chunk int8 quantization with error feedback (the residual is
carried to the next step, preserving convergence; cf. 1-bit Adam lineage).

``compressed_psum`` is used inside a shard_map over the pod axis (see
launch/train.py --grad-compression); quantize/dequantize + error feedback
are pure functions, property-tested in tests/test_compression.py.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

CHUNK = 2048


class CompressionState(NamedTuple):
    residual: Any  # error-feedback residuals, same tree as grads


def init_compression_state(grads) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads))


def quantize_int8(x: jax.Array, scale: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Per-chunk symmetric int8 quantization. Returns (q, scales).
    A precomputed ``scale`` (e.g. the pmax across devices) may be passed so
    the int32 sum of payloads dequantizes exactly."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % CHUNK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, CHUNK)
    if scale is None:
        scale = jnp.max(jnp.abs(flat), axis=1) / 127.0
    q = jnp.clip(jnp.round(flat / jnp.maximum(scale[:, None], 1e-12)), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = q.astype(jnp.float32) * scale[:, None]
    n = 1
    for s in shape:
        n *= s
    return flat.reshape(-1)[:n].reshape(shape)


def compress_with_feedback(g: jax.Array, residual: jax.Array, scale=None):
    """(quantized payload, new residual). dequantize(payload) + residual' == g + residual."""
    target = g.astype(jnp.float32) + residual
    q, scale = quantize_int8(target, scale)
    approx = dequantize_int8(q, scale, g.shape)
    new_residual = target - approx
    return (q, scale), new_residual


def compressed_psum(g: jax.Array, residual: jax.Array, axis_name: str):
    """Error-feedback int8 all-reduce MEAN over ``axis_name`` (inside a
    shard_map over the pod/DCN axis).

    The scale is pmax-shared first so every device quantizes on the same
    grid; the int8 payloads then sum EXACTLY in int32 and dequantize with
    the shared scale. Wire format: 1 byte/elem + 1 f32 scale per CHUNK
    (~4x less DCN traffic than f32 grads). Error feedback carries the
    local quantization error into the next step.
    """
    target = g.astype(jnp.float32) + residual
    _, local_scale = quantize_int8(target)
    shared_scale = jax.lax.pmax(local_scale, axis_name)
    (q, scale), new_residual = compress_with_feedback(g, residual, shared_scale)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    out = dequantize_int8(qsum, scale, g.shape)
    n = jax.lax.psum(1, axis_name)
    return (out / n).astype(g.dtype), new_residual
