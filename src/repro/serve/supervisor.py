"""Supervised worker-process pool for the mapping service (DESIGN.md §12).

The ROADMAP's service item asks for "a process boundary over
serve/mapper.py": PR 6 made the service overload-safe *inside one
process*, but a segfaulting XLA dispatch, an OOM kill, or a plain SIGKILL
still takes every in-flight request down with it. This module is the
supervision layer:

* **Worker processes** — ``SupervisedWorkerPool`` spawns N workers
  (``multiprocessing`` *spawn* context: no forked JAX runtime state, the
  documented-safe combination). Tasks are addressed by an importable
  function path (``"module:function"``) plus a picklable payload, so the
  worker side stays import-light until real work arrives.
* **Health checks** — each worker runs a daemon heartbeat thread;
  the supervisor's monitor thread watches liveness (``Process.is_alive``)
  at a short poll interval and, when a ``hang_timeout_s`` is configured,
  kills workers that stop heartbeating mid-task (a hang is a crash that
  forgot to die).
* **Crash detection + restart** — a dead worker (any exit, including
  SIGKILL — exitcode ``-9``) is detected within one poll interval and
  respawned with CAPPED EXPONENTIAL BACKOFF (`restart_backoff_s` doubling
  per consecutive crash up to ``restart_backoff_cap_s``; a completed task
  resets the streak), so a crash-looping worker cannot hot-spin the host.
* **Re-dispatch** — the dead worker's in-flight task is put back at the
  FRONT of the queue (up to ``max_redispatch`` attempts) so its Future
  still resolves; only a task that kills ``max_redispatch + 1`` workers in
  a row fails, with a typed :class:`WorkerCrashError` that advertises
  itself ``transient`` (the service's retry/degradation ladder takes it
  from there). Zero unresolved futures is the contract, crash or not.
* **Deterministic fault injection** — the ``worker_kill`` seam of a
  ``repro.faults.FaultInjector`` is checked right after each dispatch; a
  fired fault SIGKILLs the worker the task was just sent to. Tests drive
  the whole crash->detect->restart->re-dispatch machinery with
  ``fail_at={"worker_kill": (i, ...)}`` — no timing races.

:func:`mapping_task` is the worker-side entry point the service uses: it
rebuilds the (Graph, Hierarchy, config) request from plain numpy arrays
and runs ``shared_map_direct`` — whole-request isolation. Cross-request
coalescing does not cross the process boundary; a service with
``workers=N`` trades the merged-dispatch throughput for crash isolation
(DESIGN.md §12 discusses when each wins).
"""
from __future__ import annotations

import dataclasses
import importlib
import os
import pickle
import queue as queue_mod
import signal
import threading
import time
import traceback
from collections import deque
from concurrent.futures import Future

from repro.faults import NULL_INJECTOR, FaultInjector, InjectedFault
from repro.serve.tracker import NULL_TRACKER, Tracker, safe_emit


class WorkerCrashError(RuntimeError):
    """A task's worker died (possibly repeatedly) before finishing it.

    ``transient = True``: from the caller's perspective a crashed worker
    is retry-worthy infrastructure failure, not a property of the request
    (the service's RetryPolicy reads this attribute generically).
    """

    transient = True

    def __init__(self, message: str, redispatches: int = 0,
                 exitcode: int | None = None):
        super().__init__(message)
        self.redispatches = redispatches
        self.exitcode = exitcode


class WorkerPoolClosedError(RuntimeError):
    """Task abandoned because the pool shut down first."""


class WorkerTaskError(RuntimeError):
    """A worker task raised an exception that could not be pickled back;
    carries its repr + traceback text instead."""


def _resolve_fn(path: str):
    mod, _, attr = path.partition(":")
    if not attr:
        raise ValueError(f"task path {path!r} is not 'module:function'")
    return getattr(importlib.import_module(mod), attr)


def _worker_main(wid: int, inbox, outbox, hb_interval_s: float) -> None:
    """Worker process body: heartbeat thread + task loop.

    Messages in: ``(task_id, fn_path, payload)`` or ``None`` (shutdown).
    Messages out: ``("hb", wid)``, ``("ok", task_id, wid, result)``,
    ``("err", task_id, wid, pickled_exc_or_text)``.
    """
    signal.signal(signal.SIGINT, signal.SIG_IGN)  # parent owns shutdown
    stop = threading.Event()

    def beat():
        while not stop.wait(hb_interval_s):
            try:
                outbox.put(("hb", wid))
            except Exception:
                return

    threading.Thread(target=beat, daemon=True, name="hb").start()
    while True:
        msg = inbox.get()
        if msg is None:
            stop.set()
            return
        task_id, fn_path, payload = msg
        try:
            result = _resolve_fn(fn_path)(payload)
            outbox.put(("ok", task_id, wid, result))
        except BaseException as exc:  # noqa: BLE001 — ship it to the parent
            try:
                shipped = pickle.dumps(exc)
            except Exception:
                shipped = f"{exc!r}\n{traceback.format_exc()}"
            outbox.put(("err", task_id, wid, shipped))


@dataclasses.dataclass(eq=False)
class _Task:
    id: int
    fn_path: str
    payload: object
    future: Future
    redispatches: int = 0
    worker: int | None = None
    dispatched_at: float = 0.0


@dataclasses.dataclass(eq=False)
class _Worker:
    wid: int
    proc: object = None
    inbox: object = None
    outbox: object = None
    task: _Task | None = None
    last_hb: float = 0.0
    consecutive_crashes: int = 0
    restart_at: float = 0.0   # monotonic time before which we must not spawn
    restarts: int = 0

    def alive(self) -> bool:
        return self.proc is not None and self.proc.is_alive()


class SupervisedWorkerPool:
    """N supervised worker processes behind a Future-based ``submit``.

    Parameters
    ----------
    workers: process count.
    ctx: multiprocessing start method ("spawn" default — fork duplicates
        the parent's JAX/XLA runtime state, which is undefined behavior).
    heartbeat_s: worker heartbeat period (health signal).
    hang_timeout_s: if set, a busy worker whose heartbeats stop for this
        long is SIGKILLed (treated as a crash: restart + re-dispatch).
        None disables — mapping compute is bursty and compile times vary,
        so hang detection is opt-in.
    restart_backoff_s / restart_backoff_cap_s: capped exponential restart
        backoff per consecutive crash of the same worker slot.
    max_redispatch: how many times one task may be re-dispatched after
        killing its worker before its Future fails with WorkerCrashError.
    fault_injector: ``worker_kill`` seam — a fired occurrence SIGKILLs the
        worker the task was just dispatched to (deterministic crash tests).
    """

    def __init__(self, workers: int = 2, *, ctx: str = "spawn",
                 heartbeat_s: float = 0.2, hang_timeout_s: float | None = None,
                 restart_backoff_s: float = 0.05,
                 restart_backoff_cap_s: float = 2.0,
                 max_redispatch: int = 2, poll_s: float = 0.02,
                 fault_injector: FaultInjector = NULL_INJECTOR,
                 tracker: Tracker = NULL_TRACKER):
        import multiprocessing as mp
        self._mp = mp.get_context(ctx)
        self.heartbeat_s = float(heartbeat_s)
        self.hang_timeout_s = hang_timeout_s
        self.restart_backoff_s = float(restart_backoff_s)
        self.restart_backoff_cap_s = float(restart_backoff_cap_s)
        self.max_redispatch = int(max_redispatch)
        self.poll_s = float(poll_s)
        self.faults = fault_injector
        self.tracker = tracker
        self._lock = threading.Lock()
        self._closed = False
        self._seq = 0
        self._pending: deque[_Task] = deque()
        self._inflight: dict[int, _Task] = {}
        self._counters = {"submitted": 0, "ok": 0, "err": 0, "crashes": 0,
                          "restarts": 0, "redispatched": 0,
                          "crash_failed": 0, "killed_injected": 0,
                          "hang_kills": 0, "outbox_errors": 0}
        self._workers = {i: _Worker(wid=i) for i in range(max(int(workers), 1))}
        for w in self._workers.values():
            self._spawn(w)
        self._collector = threading.Thread(target=self._collect_loop,
                                           daemon=True, name="pool-collector")
        self._monitor = threading.Thread(target=self._monitor_loop,
                                         daemon=True, name="pool-monitor")
        self._collector.start()
        self._monitor.start()

    # ----------------------------------------------------------- frontend

    def submit(self, fn_path: str, payload) -> Future:
        """Run ``fn_path(payload)`` on some worker; Future resolves with
        the task's return value, its (re-raised) exception, or a typed
        WorkerCrashError/WorkerPoolClosedError."""
        fut: Future = Future()
        with self._lock:
            if self._closed:
                raise WorkerPoolClosedError("worker pool is closed")
            self._seq += 1
            task = _Task(id=self._seq, fn_path=fn_path, payload=payload,
                         future=fut)
            self._counters["submitted"] += 1
            self._pending.append(task)
            self._dispatch_locked()
        return fut

    def stats(self) -> dict:
        with self._lock:
            snap = dict(self._counters)
            snap["workers"] = len(self._workers)
            snap["alive"] = sum(1 for w in self._workers.values() if w.alive())
            snap["pending"] = len(self._pending)
            snap["inflight"] = len(self._inflight)
        return snap

    def close(self, wait: bool = True, timeout: float = 30.0) -> None:
        """Stop the pool. ``wait=True`` drains in-flight tasks first (up
        to ``timeout``); either way every unfinished Future is failed with
        :class:`WorkerPoolClosedError` before workers are torn down."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        if wait:
            deadline = time.monotonic() + timeout
            while time.monotonic() < deadline:
                with self._lock:
                    if not self._inflight and not self._pending:
                        break
                time.sleep(self.poll_s)
        with self._lock:
            doomed = list(self._pending) + list(self._inflight.values())
            self._pending.clear()
            self._inflight.clear()
            workers = list(self._workers.values())
        exc = WorkerPoolClosedError("worker pool closed before the task "
                                    "completed")
        for task in doomed:
            if not task.future.done():
                task.future.set_exception(exc)
        for w in workers:
            if w.alive():
                try:
                    w.inbox.put(None)
                except Exception:
                    pass
        t0 = time.monotonic()
        for w in workers:
            if w.proc is not None:
                w.proc.join(max(0.0, 1.0 - (time.monotonic() - t0)))
                if w.proc.is_alive():
                    w.proc.kill()
                    w.proc.join(1.0)

    def __enter__(self) -> "SupervisedWorkerPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close(wait=exc[0] is None)

    # --------------------------------------------------------- scheduling

    def _spawn(self, w: _Worker) -> None:
        """(Re)start one worker slot with FRESH queues in both directions.

        Queues are strictly per-worker and single-writer: the parent is
        the only writer of the inbox, the worker the only writer of its
        outbox. A shared outbox would be a liveness hazard — an
        ``mp.Queue`` guards its pipe with a cross-process write lock, and
        a worker SIGKILLed mid-``put`` dies HOLDING it, silently wedging
        every surviving worker's sends (observed in the burst-kill test).
        With one writer per queue, a kill can only poison the dead
        worker's own queues, which are discarded here on respawn.
        """
        w.inbox = self._mp.Queue()
        w.outbox = self._mp.Queue()
        w.proc = self._mp.Process(
            target=_worker_main,
            args=(w.wid, w.inbox, w.outbox, self.heartbeat_s),
            daemon=True, name=f"mapper-worker-{w.wid}")
        w.proc.start()
        w.last_hb = time.monotonic()

    def _dispatch_locked(self) -> None:
        """Assign pending tasks to idle live workers. Caller holds _lock."""
        kills = []
        for w in self._workers.values():
            if not self._pending:
                break
            if w.task is None and w.alive():
                task = self._pending.popleft()
                task.worker = w.wid
                task.dispatched_at = time.monotonic()
                w.task = task
                self._inflight[task.id] = task
                try:
                    w.inbox.put((task.id, task.fn_path, task.payload))
                except Exception:
                    # broken pipe to a dying worker: requeue, let the
                    # monitor handle the corpse.
                    w.task = None
                    self._inflight.pop(task.id, None)
                    task.worker = None
                    self._pending.appendleft(task)
                    continue
                try:
                    self.faults.check("worker_kill")
                except InjectedFault:
                    kills.append(w)
        for w in kills:  # SIGKILL outside the per-worker bookkeeping
            self._counters["killed_injected"] += 1
            safe_emit(self.tracker.event, "worker_kill_injected", wid=w.wid)
            try:
                os.kill(w.proc.pid, signal.SIGKILL)
            except OSError:
                pass

    # ------------------------------------------------------ result intake

    def _collect_loop(self) -> None:
        """Drain every live worker's private outbox (non-blocking polls —
        never a blocking read on a queue whose writer might be killed
        mid-frame)."""
        while True:
            with self._lock:
                if self._closed:
                    return
                outboxes = [w.outbox for w in self._workers.values()
                            if w.outbox is not None]
            got_any = False
            for q in outboxes:
                while True:
                    try:
                        msg = q.get_nowait()
                    except queue_mod.Empty:
                        break
                    except Exception:
                        with self._lock:
                            self._counters["outbox_errors"] += 1
                        break
                    got_any = True
                    self._handle_msg(msg)
            if not got_any:
                time.sleep(self.poll_s)

    def _handle_msg(self, msg) -> None:
        kind = msg[0]
        if kind == "hb":
            with self._lock:
                w = self._workers.get(msg[1])
                if w is not None:
                    w.last_hb = time.monotonic()
            return
        _, task_id, wid, body = msg
        with self._lock:
            task = self._inflight.pop(task_id, None)
            w = self._workers.get(wid)
            if w is not None:
                if w.task is task and task is not None:
                    w.task = None
                w.consecutive_crashes = 0  # a finished task ends a streak
            self._counters["ok" if kind == "ok" else "err"] += 1
            self._dispatch_locked()
        if task is None:
            return  # late result for a task already re-dispatched/failed
        if kind == "ok":
            if not task.future.done():
                task.future.set_result(body)
        else:
            exc: BaseException
            if isinstance(body, (bytes, bytearray)):
                try:
                    exc = pickle.loads(body)
                except Exception:
                    exc = WorkerTaskError("worker task failed "
                                          "(unpicklable exception)")
            else:
                exc = WorkerTaskError(str(body))
            if not task.future.done():
                task.future.set_exception(exc)

    # --------------------------------------------------------- supervision

    def _monitor_loop(self) -> None:
        while True:
            time.sleep(self.poll_s)
            now = time.monotonic()
            crashed: list[tuple[_Worker, _Task | None, int | None]] = []
            exhausted: list[tuple[_Task, WorkerCrashError]] = []
            with self._lock:
                if self._closed:
                    return
                for w in self._workers.values():
                    if w.proc is None:
                        continue
                    if w.alive():
                        if (self.hang_timeout_s is not None
                                and w.task is not None
                                and now - w.last_hb > self.hang_timeout_s
                                and now - w.task.dispatched_at
                                > self.hang_timeout_s):
                            self._counters["hang_kills"] += 1
                            safe_emit(self.tracker.event, "worker_hang_kill",
                                      wid=w.wid)
                            try:
                                os.kill(w.proc.pid, signal.SIGKILL)
                            except OSError:
                                pass
                        continue
                    # dead worker slot: drain happens never again — its
                    # outbox may hold a torn frame, so it is dropped (a
                    # completed-but-unreported result is simply recomputed
                    # via re-dispatch).
                    exitcode = w.proc.exitcode
                    task = w.task
                    w.task = None
                    w.proc = None
                    w.outbox = None
                    w.consecutive_crashes += 1
                    backoff = min(
                        self.restart_backoff_s
                        * (2.0 ** (w.consecutive_crashes - 1)),
                        self.restart_backoff_cap_s)
                    w.restart_at = now + backoff
                    self._counters["crashes"] += 1
                    crashed.append((w, task, exitcode))
                    if task is not None and task.id in self._inflight:
                        del self._inflight[task.id]
                        if task.redispatches < self.max_redispatch:
                            task.redispatches += 1
                            task.worker = None
                            self._counters["redispatched"] += 1
                            self._pending.appendleft(task)  # keep its turn
                        else:
                            self._counters["crash_failed"] += 1
                            exhausted.append((task, WorkerCrashError(
                                f"worker died {task.redispatches + 1} "
                                f"times running this task "
                                f"(last exitcode {exitcode})",
                                redispatches=task.redispatches,
                                exitcode=exitcode)))
                # respawn slots whose backoff has elapsed
                for w in self._workers.values():
                    if w.proc is None and now >= w.restart_at:
                        self._spawn(w)
                        w.restarts += 1
                        self._counters["restarts"] += 1
                        safe_emit(self.tracker.event, "worker_restart",
                                  wid=w.wid,
                                  consecutive_crashes=w.consecutive_crashes)
                self._dispatch_locked()
            # future resolution OUTSIDE the lock: set_exception runs done-
            # callbacks synchronously (the mapping service hooks one).
            for task, exc in exhausted:
                if not task.future.done():
                    task.future.set_exception(exc)
            for w, task, exitcode in crashed:
                safe_emit(self.tracker.event, "worker_crash", wid=w.wid,
                          exitcode=exitcode,
                          had_task=task is not None)


# ---------------------------------------------------------------------------
# the mapping service's worker-side task
# ---------------------------------------------------------------------------

def mapping_task(payload: dict) -> dict:
    """Worker entry point: rebuild the request from plain arrays and run
    the direct mapping path. Heavy imports stay inside the function so the
    supervisor module (and crash tests using cheap tasks) never pay them.

    ``payload["timeout_s"]`` (remaining deadline budget at dispatch time)
    becomes a worker-local monotonic deadline enforced at the multisection
    level checkpoints — monotonic clocks are not comparable across
    processes, so the parent ships a duration, not an instant.
    """
    import numpy as np

    from repro.core.api import SharedMapConfig, shared_map_direct
    from repro.core.graph import assemble_padded
    from repro.core.hierarchy import Hierarchy
    from repro.serve.admission import DeadlineExceededError

    deadline = None
    if payload.get("timeout_s") is not None:
        deadline = time.monotonic() + float(payload["timeout_s"])

    def checkpoint():
        if deadline is not None and time.monotonic() > deadline:
            raise DeadlineExceededError("deadline exceeded in worker")

    g = assemble_padded(np.asarray(payload["vwgt"], np.float32),
                        np.asarray(payload["rows"], np.int32),
                        np.asarray(payload["cols"], np.int32),
                        np.asarray(payload["ewgt"], np.float32),
                        int(payload["n"]), int(payload["N"]),
                        int(payload["M"]))
    h = Hierarchy(a=tuple(payload["a"]), d=tuple(payload["d"]))
    cfg = SharedMapConfig(**payload["cfg"])
    res = shared_map_direct(g, h, cfg, checkpoint=checkpoint,
                            resident=payload.get("resident"))
    return {"pe_of": np.asarray(res.pe_of), "J": float(res.J),
            "stats": res.stats}


def echo_task(payload: dict) -> dict:
    """Trivial task for pool tests/benchmarks: optional sleep, optional
    self-SIGKILL (a worker crash with no injector involved), then echo."""
    if payload.get("sleep_s"):
        time.sleep(float(payload["sleep_s"]))
    if payload.get("die"):
        os.kill(os.getpid(), signal.SIGKILL)
    if payload.get("raise"):
        raise ValueError(str(payload["raise"]))
    return payload
