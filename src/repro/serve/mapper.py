"""Batched, cached, overload-safe mapping service (DESIGN.md §9–§10).

Turns the one-shot ``shared_map`` entry point into a long-lived service for
heavy mapping traffic. Three throughput mechanisms, all bit-transparent:

* **Cross-request coalescing** — every in-flight request runs on a
  ``core.multisection.LevelPlanner``; a single scheduler thread gathers the
  per-level :class:`PlanGroup`s of ALL active planners, merges groups with
  equal ``exec_key`` and dispatches each merged set as ONE stacked vmapped
  ``_batched_partition`` call. vmap lanes are independent, so each
  request's result is bit-identical to the direct path (tested).
* **Content-addressed result cache** — requests are fingerprinted by their
  real CSR arrays + hierarchy vector + config; repeats are answered from
  an LRU cache in microseconds. Concurrent identical requests dedup onto
  one in-flight computation.
* **Warmup** — :meth:`MappingService.warmup` pre-populates the process's
  jit/program cache for the expected bucket shapes.

And a robustness layer (PR 6) that makes the service survive bursty,
adversarial load — mapping sits in the launch critical path:

* **Admission control + backpressure** — bounded waiting queue and bounded
  in-flight set (``serve/admission.py``). Overflow is LOAD-SHED with an
  explicit :class:`ServiceOverloadError` (never silent queueing); a
  higher-priority arrival preempts the lowest-priority waiter instead.
  ``submit(..., deadline_s=...)`` cancels work past its deadline both in
  the queue and mid-pipeline (cooperative checkpoints between
  multisection levels).
* **Fault containment + retries** — a failed dispatch fails only the
  requests riding in it: the merged batch is re-executed per request
  (isolation), transient errors (injected faults, OOM/RESOURCE_EXHAUSTED)
  are retried with exponential backoff, and the scheduler thread never
  dies. Every accepted Future resolves — with a result or a typed error —
  on success, failure, deadline, ``close()``, or interpreter teardown.
* **Graceful degradation** — under overload (opt-in) or after repeated
  transient failures (default), requests fall down a quality ladder:
  cached-nearby result → ``fast`` preset → greedy baseline
  (``core/baselines.greedy_baseline``); the level taken is reported in
  ``stats["degradation"]``. The serving-side analogue of the paper
  family's fast/eco/strong quality spectrum.
* **Observability + fault injection** — a pluggable :class:`Tracker`
  (``serve/tracker.py``) streams admission/shed/retry/deadline/cache
  counters to log, memory, or JSON-lines sinks, and a seeded
  ``repro.faults.FaultInjector`` exercises the dispatch/cache/finalize
  seams deterministically (shared with the trainer).

And a durability + supervision layer (PR 8, DESIGN.md §12) that makes the
service restartable, multi-process, and self-checking:

* **Durable result store** — ``store_path=`` plugs a crash-safe
  content-addressed :class:`~repro.serve.store.ResultStore` in as the
  persistence tier behind the LRU: every full-quality result is atomically
  published to disk, and a restarted service warm-starts — an LRU miss
  falls through to the store and serves the bit-identical result the same
  request would recompute. Corrupt/truncated entries are checksum-detected,
  quarantined (``stats["store"]["corrupt"]``), and never returned.
* **Supervised workers** — ``workers=N`` executes requests in
  ``serve/supervisor.py`` worker PROCESSES (spawned, heartbeat-monitored):
  a worker crash — segfault, OOM kill, SIGKILL — is detected, the worker
  restarts with capped exponential backoff, and its in-flight request is
  re-dispatched so the Future still resolves. A repeatedly-crashing
  request fails with a typed transient ``WorkerCrashError`` and falls into
  the normal degradation ladder. (Process isolation supersedes
  cross-request coalescing: worker mode trades merged dispatches for
  crash containment.)
* **Shadow verification** — ``shadow_verify_fraction=p`` re-executes that
  fraction of ``strategy="device"`` results against the bitwise host-ref
  twin (``resident=False``); a divergence is recorded to the tracker,
  the lying entry is evicted + quarantined, and the device pipeline is
  quarantined for the rest of the session (subsequent device requests run
  the host-ref path). ``stats["shadow"]`` carries the sample counters.

Usage::

    svc = MappingService(tracker=JsonlTracker("mapper.jsonl"))
    with svc.installed():              # route shared_map through the service
        res = shared_map(g, h)         # coalesced + cached transparently
    fut = svc.submit(g, h, cfg, priority=1, deadline_s=0.5)
    res = await svc.amap(g, h)
    svc.close()

    svc = MappingService(store_path="/var/cache/mapper", workers=2)

The non-plannable strategies (``naive``/``queue``) fall back to the direct
path on a small worker pool — still cached and admission-controlled,
never coalesced.
"""
from __future__ import annotations

import asyncio
import atexit
import dataclasses
import hashlib
import threading
import time
import weakref
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from contextlib import contextmanager

import numpy as np

from repro.core import api as capi
from repro.core.api import SharedMapConfig, SharedMapResult
from repro.core.baselines import greedy_baseline
from repro.core.graph import Graph, from_edges
from repro.core.hierarchy import Hierarchy
from repro.core.mapping import evaluate_J
from repro.core.multisection import (STRATEGIES, LevelPlanner, PlanGroup,
                                     _ell_deg_for, _next_pow2,
                                     dispatch_group_batch,
                                     execute_group_batch, fetch_group_batch,
                                     host_graph_from)
from repro.core.partition import num_levels
from repro.core.refine import resolve_backend
from repro.core.taskgraph import TaskGraph
from repro.faults import NULL_INJECTOR, FaultInjector, _hash_uniform
from repro.serve.admission import (ADMIT, ADMIT_DEGRADED, PREEMPT, SHED,
                                   AdmissionController, DeadlineExceededError,
                                   RetryPolicy, ServiceClosedError,
                                   ServiceOverloadError)
from repro.serve.store import ResultStore
from repro.serve.supervisor import SupervisedWorkerPool
from repro.serve.tracker import NULL_TRACKER, Tracker, safe_emit

_PLANNABLE = ("bucket", "layer", "device")
_PRESETS = ("fast", "eco", "strong")

# degradation ladder levels (stats["degradation"]["level"])
DEGRADE_FULL = 0           # full-quality result (the normal path)
DEGRADE_CACHED_NEARBY = 1  # cached result for the same graph, other config
DEGRADE_FAST_PRESET = 2    # recomputed with the cheapest preset
DEGRADE_GREEDY = 3         # greedy baseline floor (no multisection)


def graph_fingerprint(g: Graph, h: Hierarchy,
                      tg: TaskGraph | None = None) -> bytes:
    """Content address of the (graph, hierarchy) pair alone — the REAL CSR
    arrays (padding never affects planning) plus the hierarchy vectors.
    Keys the degradation ladder's cached-nearby index: any cached result
    for the same graph+hierarchy is 'nearby' whatever its config.

    When the request arrived as a workload-layer :class:`TaskGraph`, its
    canonical-form ``fingerprint()`` substitutes for hashing the doubled
    CSR — cheaper, and stable across whatever edge order the producer
    emitted (PR 10)."""
    hs = hashlib.blake2b(digest_size=16)
    if tg is not None:
        hs.update(b"TG")
        hs.update(tg.fingerprint())
        hs.update(repr((tuple(h.a), tuple(h.d))).encode())
        return hs.digest()
    n = int(g.n)
    m = int(g.m)
    for arr in (np.asarray(g.vwgt)[:n], np.asarray(g.rows)[:m],
                np.asarray(g.cols)[:m], np.asarray(g.ewgt)[:m]):
        a = np.ascontiguousarray(arr)
        hs.update(str(a.dtype).encode())
        hs.update(a.tobytes())
    hs.update(repr((n, m, tuple(h.a), tuple(h.d))).encode())
    return hs.digest()


def request_fingerprint(g: Graph, h: Hierarchy, cfg: SharedMapConfig,
                        tg: TaskGraph | None = None) -> bytes:
    """Content address of a mapping request: the graph fingerprint plus
    every config field that influences the result. ``backend`` enters
    resolved, so auto/xla hit the same entry off-TPU."""
    hs = hashlib.blake2b(digest_size=16)
    hs.update(graph_fingerprint(g, h, tg))
    hs.update(repr((float(cfg.eps), cfg.preset, cfg.strategy, int(cfg.seed),
                    bool(cfg.adaptive), resolve_backend(cfg.backend),
                    bool(cfg.refine_mapping))).encode())
    return hs.digest()


def validate_request(g: Graph, h: Hierarchy, cfg: SharedMapConfig) -> None:
    """Reject malformed requests at the service boundary with a clear
    ``ValueError`` instead of an opaque scheduler-thread error surfacing
    through the Future (or worse, garbage output)."""
    n = int(g.n)
    m = int(g.m)
    if n <= 0:
        raise ValueError("empty graph: n=0 vertices (nothing to map)")
    if n > g.N or m > g.M:
        raise ValueError(f"graph counts exceed padded shapes: "
                         f"n={n} > N={g.N} or m={m} > M={g.M}")
    if h.k > n:
        raise ValueError(f"hierarchy needs k={h.k} PEs but the graph has "
                         f"only n={n} vertices (k > N is unmappable)")
    if m > 0:
        rows = np.asarray(g.rows)[:m]
        cols = np.asarray(g.cols)[:m]
        if int(rows.min()) < 0 or int(rows.max()) >= n \
                or int(cols.min()) < 0 or int(cols.max()) >= n:
            raise ValueError(f"edge endpoints out of range [0, {n}): "
                             "rows/cols reference padding or negative ids")
    if not (0.0 < float(cfg.eps) < 1.0):
        raise ValueError(f"imbalance eps must be in (0, 1), got {cfg.eps}")
    if cfg.strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {cfg.strategy!r}; "
                         f"expected one of {STRATEGIES}")
    if cfg.preset not in _PRESETS:
        raise ValueError(f"unknown preset {cfg.preset!r}; "
                         f"expected one of {_PRESETS}")


@dataclasses.dataclass(eq=False)  # identity equality: requests live in lists
class _Request:
    g: Graph
    h: Hierarchy
    cfg: SharedMapConfig
    fp: bytes
    gfp: bytes
    futures: list[Future]
    planner: LevelPlanner | None = None
    priority: int = 0
    deadline: float | None = None   # absolute time.monotonic()
    seq: int = 0
    started: bool = False           # counted in admission.inflight
    degradation: dict | None = None  # set when served below full quality


def _dummy_host_graph(N: int, M: int):
    """A path graph filling the (N, M) padded shape, for warmup compiles."""
    if N < 2 or M < 2:
        raise ValueError(f"warmup shape too small: N={N}, M={M}")
    e = max(min(N - 1, M // 2), 1)
    u = np.arange(e, dtype=np.int64)
    return host_graph_from(from_edges(N, u, u + 1, N=N, M=M))


# Services alive at interpreter exit: fail their pending futures instead of
# leaking them when the daemon scheduler thread is killed mid-flight.
_LIVE_SERVICES: "weakref.WeakSet[MappingService]" = weakref.WeakSet()


@atexit.register
def _close_live_services() -> None:
    for svc in list(_LIVE_SERVICES):
        try:
            svc.close(wait=False)
        except Exception:
            pass


class MappingService:
    """Async mapping service: concurrent ``(Graph, Hierarchy, config)``
    requests, coalesced dispatches, LRU result cache, warmup, admission
    control, deadlines, fault containment, graceful degradation.

    Parameters
    ----------
    cache_entries: LRU bound of the result cache (0 disables caching).
    batch_window_s: how long the scheduler waits after a request arrives
        on an idle service before planning, so a concurrent burst lands in
        the same coalesced dispatches.
    merge_across_requests: dispatch same-``exec_key`` groups of different
        requests as one batch (False = per-request dispatches).
    pad_batch_pow2: pad merged batches to the next power of two so XLA
        compiles O(log B) batch widths per shape.
    fallback_workers: thread pool size for the non-plannable strategies,
        finalization, and degraded reruns.
    max_inflight: bound on concurrently ACTIVE requests (planners being
        stepped + fallback jobs); excess waits in the queue (backpressure).
    max_queue: bound on accepted-but-waiting requests; overflow is shed
        with :class:`ServiceOverloadError` (or preempts a lower-priority
        waiter, or degrades — see ``degrade_on_overload``).
    degrade_at: fraction of ``max_queue`` at which new arrivals are served
        degraded instead of full quality (only with ``degrade_on_overload``).
    degrade_on_overload: serve overflow along the quality ladder
        (cached-nearby → fast preset → greedy) instead of shedding it.
        Off by default: explicit load-shedding is the predictable contract;
        opt in for availability-over-quality deployments.
    degrade_on_failure: after transient-failure retries are exhausted,
        serve the request degraded instead of failing its Future (default
        on — deterministic errors always propagate regardless).
    retry: :class:`RetryPolicy` for transient dispatch/finalize failures.
    tracker: metrics sink (``serve/tracker.py``); sink errors never
        propagate into the serving path.
    fault_injector: seeded ``repro.faults.FaultInjector`` exercised at the
        dispatch/cache/finalize seams (tests/benchmarks) and forwarded to
        the store (``store_write``) and supervisor (``worker_kill``) seams.
    validate: check requests at the boundary (``validate_request``) and
        raise ``ValueError`` synchronously from :meth:`submit`.
    store_path: directory for the crash-safe persistent result store
        (``serve/store.py``); None disables persistence. An LRU miss falls
        through to the store, so a restarted service with the same path
        warm-starts its cache bit-identically.
    store: an already-constructed :class:`ResultStore` (overrides
        ``store_path``; lets tests share one store between services).
    workers: > 0 executes requests in that many SUPERVISED WORKER
        PROCESSES (``serve/supervisor.py``) instead of in-process: crashes
        (incl. SIGKILL) are detected, workers restart with capped backoff,
        in-flight requests are re-dispatched. Trades cross-request
        coalescing for crash isolation. 0 (default) keeps PR 5's
        in-process execution.
    worker_kwargs: extra keyword arguments for
        :class:`SupervisedWorkerPool` (heartbeat_s, hang_timeout_s, ...).
    shadow_verify_fraction: fraction (0..1) of ``strategy="device"``
        results re-executed against the bitwise host-ref twin
        (``resident=False``). The first divergence quarantines the device
        strategy for the session (host path from then on), evicts the
        lying cache/store entry, and re-caches the trusted host result.
    """

    def __init__(self, cache_entries: int = 256, batch_window_s: float = 0.002,
                 merge_across_requests: bool = True, pad_batch_pow2: bool = True,
                 fallback_workers: int = 2, max_inflight: int = 64,
                 max_queue: int = 512, degrade_at: float = 0.75,
                 degrade_on_overload: bool = False,
                 degrade_on_failure: bool = True,
                 retry: RetryPolicy | None = None,
                 tracker: Tracker = NULL_TRACKER,
                 fault_injector: FaultInjector = NULL_INJECTOR,
                 validate: bool = True,
                 store_path: str | None = None,
                 store: ResultStore | None = None,
                 workers: int = 0,
                 worker_kwargs: dict | None = None,
                 shadow_verify_fraction: float = 0.0):
        self.cache_entries = int(cache_entries)
        self.batch_window_s = float(batch_window_s)
        self.merge_across_requests = bool(merge_across_requests)
        self.pad_batch_pow2 = bool(pad_batch_pow2)
        self.degrade_on_overload = bool(degrade_on_overload)
        self.degrade_on_failure = bool(degrade_on_failure)
        self.validate = bool(validate)
        self.retry = retry or RetryPolicy()
        self.tracker = tracker
        self.faults = fault_injector
        self.store = store
        if self.store is None and store_path is not None:
            self.store = ResultStore(store_path, fault_injector=fault_injector)
        self.supervisor: SupervisedWorkerPool | None = None
        if int(workers) > 0:
            self.supervisor = SupervisedWorkerPool(
                int(workers), fault_injector=fault_injector, tracker=tracker,
                **(worker_kwargs or {}))
        self.shadow_verify_fraction = float(shadow_verify_fraction)
        self._shadow_seq = 0
        self._device_quarantined = False
        self.admission = AdmissionController(max_inflight=max_inflight,
                                             max_queue=max_queue,
                                             degrade_at=degrade_at)
        self._cv = threading.Condition()
        self._queue: list[_Request] = []
        self._pending: dict[bytes, _Request] = {}  # queued + active, by fp
        self._seq = 0
        self._closed = False
        self._abort = False
        self._thread: threading.Thread | None = None
        self._fallback = ThreadPoolExecutor(
            max_workers=max(1, fallback_workers),
            thread_name_prefix="mapper-fallback")
        self._cache: OrderedDict[bytes, SharedMapResult] = OrderedDict()
        self._by_graph: dict[bytes, bytes] = {}  # gfp -> freshest cached fp
        self._lock = threading.Lock()  # cache + telemetry
        self.telemetry = {
            "requests": 0,
            "inflight_dedup": 0,
            "result_cache": {"hits": 0, "misses": 0, "evictions": 0},
            "coalesce": {"dispatches": 0, "groups": 0, "members": 0,
                         "padded_lanes": 0},
            "compile_cache": {"hits": 0, "misses": 0},
            "warmup": {"programs": 0, "seconds": 0.0},
            "faults": {"dispatch_failures": 0, "retries": 0, "isolated": 0,
                       "contained": 0, "cache_faults": 0, "degraded": 0},
            "shadow": {"sampled": 0, "matched": 0, "mismatched": 0},
        }
        _LIVE_SERVICES.add(self)

    # ------------------------------------------------------------- frontend

    def submit(self, g: Graph | TaskGraph, h: Hierarchy,
               config: SharedMapConfig | None = None, *,
               priority: int = 0, deadline_s: float | None = None,
               on_shed: str = "raise") -> Future:
        """Enqueue a mapping request; returns a Future[SharedMapResult].

        ``priority``: larger = more important; under a full queue a
        higher-priority arrival preempts the lowest-priority waiter.
        ``deadline_s``: relative deadline; the request is cancelled with
        :class:`DeadlineExceededError` if still queued — or between
        multisection levels — once it expires.
        ``on_shed``: "raise" surfaces :class:`ServiceOverloadError`
        synchronously; "future" returns it on the Future instead (what
        :meth:`submit_many` uses so one shed cannot poison a batch).

        Raises ``ValueError`` synchronously for malformed inputs (empty
        graph, k > n, out-of-range edges, bad eps/strategy/preset) and
        :class:`ServiceClosedError` after :meth:`close`.
        """
        cfg = config or SharedMapConfig()
        tg = g if isinstance(g, TaskGraph) else None
        if tg is not None:
            g = tg.to_graph()
        if self.validate:
            validate_request(g, h, cfg)
        fut: Future = Future()
        deadline = None
        if deadline_s is not None:
            deadline = time.monotonic() + float(deadline_s)
        fp = request_fingerprint(g, h, cfg, tg)
        cached = self._cache_get(fp)
        if cached is not None:
            fut.set_result(self._result_copy(cached, cache_hit=True))
            return fut
        with self._lock:
            self.telemetry["requests"] += 1
            self.telemetry["result_cache"]["misses"] += 1
        safe_emit(self.tracker.count, "service.cache.miss")
        if deadline is not None and deadline <= time.monotonic():
            self._count_deadline_miss()
            fut.set_exception(DeadlineExceededError(
                f"deadline of {deadline_s}s already expired at submit"))
            return fut
        with self._cv:
            if self._closed:
                raise ServiceClosedError("MappingService is closed")
            inflight = self._pending.get(fp)
            if inflight is not None:
                # identical request already queued/active: one computation
                inflight.futures.append(fut)
                with self._lock:
                    self.telemetry["inflight_dedup"] += 1
                return fut
            return self._admit_new(g, h, cfg, fp, fut, priority, deadline,
                                   on_shed, tg=tg)

    def _admit_new(self, g, h, cfg, fp, fut, priority, deadline,
                   on_shed, tg=None) -> Future:
        """Admission decision for a non-cached, non-dedup request. Caller
        holds ``_cv``."""
        adm = self.admission
        waiting = min(((r.priority, -r.seq) for r in self._queue),
                      default=None)
        decision = adm.decide(priority, waiting[0] if waiting else None,
                              degrade_ok=self.degrade_on_overload)
        degradation = None
        if decision == PREEMPT:
            victim = min(self._queue, key=lambda r: (r.priority, -r.seq))
            self._queue.remove(victim)
            adm.note_dequeued()
            adm.note_shed(preempted=True)
            safe_emit(self.tracker.count, "service.preempted")
            safe_emit(self.tracker.event, "shed", reason="preempted",
                      priority=victim.priority, by_priority=priority)
            self._fail(victim, ServiceOverloadError(
                "preempted by a higher-priority request",
                queued=adm.queued, inflight=adm.inflight))
            decision = ADMIT_DEGRADED if (
                self.degrade_on_overload
                and adm.queued >= adm.soft_bound()) else ADMIT
        if decision == SHED:
            if self.degrade_on_overload:
                return self._serve_inline_degraded(g, h, cfg, fut,
                                                  reason="overload", tg=tg)
            adm.note_shed()
            safe_emit(self.tracker.count, "service.shed")
            safe_emit(self.tracker.event, "shed", reason="queue_full",
                      queued=adm.queued, inflight=adm.inflight)
            exc = ServiceOverloadError(
                f"mapping queue full ({adm.queued} waiting, "
                f"{adm.inflight} in flight); request shed",
                queued=adm.queued, inflight=adm.inflight,
                retry_after_s=0.05 * max(adm.queued, 1))
            if on_shed == "raise":
                raise exc
            fut.set_exception(exc)
            return fut
        if decision == ADMIT_DEGRADED and cfg.preset != "fast":
            # soft overload: trade quality for queue drain speed — the
            # request is served with the cheapest preset, cached under the
            # DEGRADED config's fingerprint (never the original's).
            cfg = dataclasses.replace(cfg, preset="fast")
            fp = request_fingerprint(g, h, cfg, tg)
            degradation = {"level": DEGRADE_FAST_PRESET,
                           "mode": "fast_preset", "reason": "overload"}
            adm.note_degraded()
            self._count_fault("degraded")
            safe_emit(self.tracker.count, "service.degraded",
                      mode="fast_preset")
            cached = self._cache_get(fp)
            if cached is not None:
                fut.set_result(self._result_copy(cached, cache_hit=True,
                                                 degradation=degradation))
                return fut
            dedup = self._pending.get(fp)
            if dedup is not None:
                dedup.futures.append(fut)
                return fut
        self._seq += 1
        req = _Request(g=g, h=h, cfg=cfg, fp=fp,
                       gfp=graph_fingerprint(g, h, tg), futures=[fut],
                       priority=priority, deadline=deadline, seq=self._seq,
                       degradation=degradation)
        self._pending[fp] = req
        self._queue.append(req)
        adm.note_queued()
        safe_emit(self.tracker.count, "service.admitted")
        self._ensure_thread()
        self._cv.notify_all()
        return fut

    def submit_many(self, requests, *, priority: int = 0,
                    deadline_s: float | None = None) -> list[Future]:
        """Atomically enqueue a burst of ``(g, h, config)`` requests.

        All of them are admitted in ONE scheduler iteration, so the merged
        batch compositions (and therefore the compiled batch widths) are
        deterministic for a given burst — independent of caller timing.

        Per-request failures (validation errors, shed requests) come back
        as failed Futures instead of raising, so one bad or shed request
        never poisons its siblings in the batch.
        """
        futs = []
        with self._cv:  # Condition wraps an RLock: nested submit is fine
            for (g, h, cfg) in requests:
                try:
                    futs.append(self.submit(g, h, cfg, priority=priority,
                                            deadline_s=deadline_s,
                                            on_shed="future"))
                except Exception as exc:
                    f: Future = Future()
                    f.set_exception(exc)
                    futs.append(f)
        return futs

    def map(self, g: Graph | TaskGraph, h: Hierarchy,
            config: SharedMapConfig | None = None, *,
            priority: int = 0,
            deadline_s: float | None = None) -> SharedMapResult:
        """Blocking request (the ``shared_map`` route when installed)."""
        return self.submit(g, h, config, priority=priority,
                           deadline_s=deadline_s).result()

    async def amap(self, g: Graph, h: Hierarchy,
                   config: SharedMapConfig | None = None, *,
                   priority: int = 0,
                   deadline_s: float | None = None) -> SharedMapResult:
        """Asyncio request."""
        return await asyncio.wrap_future(
            self.submit(g, h, config, priority=priority,
                        deadline_s=deadline_s))

    # -------------------------------------------------------------- warmup

    def warmup(self, shapes, ks, preset: str = "eco", backend: str = "auto",
               eps: float = 0.03, batch_sizes=(1, 2, 4, 8),
               ell_degs=None) -> dict:
        """Pre-compile the bucket executables for the expected traffic.

        ``shapes``: (N, M) padded bucket shapes (powers of two, as the
        bucket scheduler produces); ``ks``: sub-partition arities;
        ``batch_sizes``: coalesced batch widths to cover (the service pads
        merged batches to powers of two by default, so a handful of widths
        covers all traffic). ``ell_degs`` optionally pins the static ELL
        degree caps to warm for the kernel backend (default: derived from
        the dummy graph, which is what xla — the common case — ignores).
        """
        backend = resolve_backend(backend)
        t0 = time.time()
        programs = 0
        for (N, M) in shapes:
            hg = _dummy_host_graph(int(N), int(M))
            degs = tuple(ell_degs) if ell_degs is not None \
                else (_ell_deg_for([hg], backend),)
            for k in ks:
                lv = num_levels(int(N), int(k))
                for deg in degs:
                    for B in batch_sizes:
                        gr = PlanGroup(
                            members=[hg] * int(B), N=int(N), M=int(M),
                            arity=int(k), levels=lv, preset=preset,
                            backend=backend, deg=deg,
                            eps=[float(eps)] * int(B),
                            salts=list(range(int(B))))
                        execute_group_batch([gr], self.telemetry["compile_cache"])
                        programs += 1
        dt = time.time() - t0
        with self._lock:
            self.telemetry["warmup"]["programs"] += programs
            self.telemetry["warmup"]["seconds"] += dt
        return {"programs": programs, "seconds": dt}

    # ---------------------------------------------------------- install / cm

    def install(self) -> "MappingService":
        """Route ``core.api.shared_map`` through this service."""
        capi.install_service(self)
        return self

    def uninstall(self) -> None:
        if capi.current_service() is self:
            capi.install_service(None)

    @contextmanager
    def installed(self):
        prev = capi.install_service(self)
        try:
            yield self
        finally:
            capi.install_service(prev)

    def close(self, wait: bool = True) -> None:
        """Stop the service. ``wait=True`` drains: every accepted request
        completes before return. ``wait=False`` aborts: every still-pending
        Future is failed with :class:`ServiceClosedError` BEFORE this
        returns (nothing leaks), and in-flight pipelines are cancelled at
        their next cooperative checkpoint."""
        with self._cv:
            self._closed = True
            if not wait:
                self._abort = True
            self._cv.notify_all()
        if not wait:
            self._fail_pending(ServiceClosedError(
                "MappingService closed before the request completed"))
        if self._thread is not None:
            self._thread.join(None if wait else 2.0)
        if self.supervisor is not None:
            # drain (or abort) the worker processes BEFORE the fallback
            # pool: worker done-callbacks may still submit finalize/shadow
            # jobs onto it.
            self.supervisor.close(wait=wait)
        self._fallback.shutdown(wait=wait, cancel_futures=not wait)
        self.uninstall()
        _LIVE_SERVICES.discard(self)
        safe_emit(self.tracker.flush)

    def _fail_pending(self, exc: BaseException) -> None:
        """Synchronously fail every accepted-but-unresolved request (the
        close(wait=False) / interpreter-teardown path)."""
        with self._cv:
            doomed = list(self._pending.values())
            for _ in self._queue:
                self.admission.note_dequeued()
            self._queue.clear()
        for req in doomed:
            self._fail(req, exc)

    def __enter__(self) -> "MappingService":
        return self.install()

    def __exit__(self, exc_type, *exc) -> None:
        self.uninstall()
        # deterministic teardown: a clean exit drains (every Future
        # resolves with its result); an exception exit aborts (every
        # pending Future fails with ServiceClosedError, promptly).
        self.close(wait=exc_type is None)

    def stats(self) -> dict:
        """Snapshot of the service telemetry."""
        with self._lock:
            snap = {k: (dict(v) if isinstance(v, dict) else v)
                    for k, v in self.telemetry.items()}
            snap["result_cache"]["entries"] = len(self._cache)
            snap["result_cache"]["capacity"] = self.cache_entries
            snap["shadow"]["device_quarantined"] = self._device_quarantined
        with self._cv:
            snap["admission"] = self.admission.snapshot()
        if self.store is not None:
            snap["store"] = self.store.stats()
        if self.supervisor is not None:
            snap["workers"] = self.supervisor.stats()
        # aggregation sinks (e.g. CounterTracker) also get the level-style
        # instruments counters can't carry, and their aggregated view rides
        # along in the snapshot — probed with getattr so plain count/event
        # sinks stay valid.
        gauge = getattr(self.tracker, "gauge", None)
        if callable(gauge):
            adm = snap["admission"]
            safe_emit(gauge, "service.queue_depth", adm["queued"])
            safe_emit(gauge, "service.inflight", adm["inflight"])
            safe_emit(gauge, "service.cache_entries",
                      snap["result_cache"]["entries"])
        tsnap = getattr(self.tracker, "snapshot", None)
        if callable(tsnap):
            try:
                snap["tracker"] = tsnap()
            except Exception:
                pass
        return snap

    # ------------------------------------------------------------ scheduler

    def _ensure_thread(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(target=self._loop, daemon=True,
                                            name="mapper-scheduler")
            self._thread.start()

    def _queue_wait_timeout(self) -> float | None:
        """Sleep bound while parked: wake for the earliest queued deadline."""
        deadlines = [r.deadline for r in self._queue if r.deadline is not None]
        if not deadlines:
            return None
        return max(min(deadlines) - time.monotonic(), 0.0)

    def _sweep_expired_queue(self) -> None:
        """Fail queued requests past their deadline. Caller holds ``_cv``."""
        now = time.monotonic()
        expired = [r for r in self._queue
                   if r.deadline is not None and now > r.deadline]
        for req in expired:
            self._queue.remove(req)
            self.admission.note_dequeued()
            self._deadline_miss(req)

    def _take_admissible(self) -> list[_Request]:
        """Move queued requests into the in-flight set up to the bound,
        highest priority (FIFO within a priority) first. Holds ``_cv``."""
        self._sweep_expired_queue()
        self._queue.sort(key=lambda r: (-r.priority, r.seq))
        taken = []
        while self._queue and self.admission.has_capacity():
            req = self._queue.pop(0)
            self.admission.note_dequeued()
            self.admission.note_start()
            req.started = True
            taken.append(req)
        return taken

    def _loop(self) -> None:
        active: list[_Request] = []
        while True:
            with self._cv:
                while True:
                    self._sweep_expired_queue()
                    if self._abort:
                        # close(wait=False) already failed every pending
                        # Future; just drop the in-flight state.
                        return
                    if self._closed and not self._queue and not active:
                        return
                    if active or (self._queue
                                  and self.admission.has_capacity()):
                        break
                    self._cv.wait(self._queue_wait_timeout()
                                  if self._queue else None)
                newly = self._take_admissible()
            if newly and not active and self.batch_window_s > 0:
                # idle service: hold the first arrivals briefly so a
                # concurrent burst coalesces from level 0 on.
                time.sleep(self.batch_window_s)
                with self._cv:
                    newly += self._take_admissible()
            for req in newly:
                try:
                    self._admit(req, active)
                except BaseException as exc:  # fail fast, never hang callers
                    self._fail(req, exc)
            if active:
                try:
                    self._step(active)
                except BaseException as exc:
                    # last-resort containment: _step already isolates
                    # per-request failures, so reaching here means the
                    # round itself broke — fail its requests, keep serving.
                    for req in active:
                        self._contain(req, exc)
                    active.clear()

    def _planner_checkpoint(self, req: _Request) -> None:
        """Cooperative cancellation hook run between multisection levels."""
        if self._abort:
            raise ServiceClosedError("service aborted mid-pipeline")
        if req.deadline is not None and time.monotonic() > req.deadline:
            raise DeadlineExceededError("deadline exceeded mid-pipeline")

    def _admit(self, req: _Request, active: list[_Request]) -> None:
        if self.supervisor is not None:
            # worker mode: the whole request executes in a supervised
            # process — crash isolation supersedes coalescing.
            self._submit_to_worker(req)
            return
        if req.cfg.strategy in _PLANNABLE:
            try:
                req.planner = LevelPlanner(
                    req.g, req.h, eps=req.cfg.eps, preset=req.cfg.preset,
                    seed=req.cfg.seed, adaptive=req.cfg.adaptive,
                    backend=req.cfg.backend, strategy=req.cfg.strategy,
                    resident=self._resident_override(req.cfg),
                    checkpoint=lambda req=req: self._planner_checkpoint(req))
            except BaseException as exc:
                self._fail(req, exc)
                return
            active.append(req)
        else:
            self._fallback.submit(self._run_fallback, req)

    def _resident_override(self, cfg: SharedMapConfig) -> bool | None:
        """None = the strategy's default; False = host-ref twin, forced
        once the shadow verifier has quarantined the device pipeline."""
        if cfg.strategy == "device" and self._device_quarantined:
            return False
        return None

    def _step(self, active: list[_Request]) -> None:
        """One coalesced execution round over all active planners.

        Failure containment: planning, dispatch and advance are guarded
        per request or per merged set; a failure removes only the requests
        it belongs to — the round (and the scheduler thread) survives.
        """
        now = time.monotonic()
        for req in list(active):  # mid-pipeline deadline cancellation
            if req.deadline is not None and now > req.deadline:
                active.remove(req)
                self._deadline_miss(req)
        plans = []
        for req in list(active):
            try:
                plans.append((req, req.planner.plan()))
            except BaseException as exc:
                active.remove(req)
                self._contain(req, exc)
        merged: OrderedDict[tuple, list[tuple[_Request, int, PlanGroup]]] = \
            OrderedDict()
        for req, groups in plans:
            for gi, gr in enumerate(groups):
                merged.setdefault(gr.exec_key, []).append((req, gi, gr))
        # dispatch ALL merged sets before fetching any: XLA dispatch is
        # async, so stacking set k+1 overlaps device compute of set k.
        inflight = []
        for entries in merged.values():
            groups = [e[2] for e in entries]
            try:
                self.faults.check("dispatch")
                if self.merge_across_requests:
                    handles = [dispatch_group_batch(
                        groups, self.telemetry["compile_cache"],
                        pad_batch_pow2=self.pad_batch_pow2)]
                    dispatches = 1
                else:
                    handles = [dispatch_group_batch(
                        [gr], self.telemetry["compile_cache"])
                        for gr in groups]
                    dispatches = len(groups)
            except BaseException as exc:
                inflight.append((entries, None, exc))
                continue
            inflight.append((entries, handles, None))
            members = sum(len(gr.members) for gr in groups)
            with self._lock:
                co = self.telemetry["coalesce"]
                co["dispatches"] += dispatches
                co["groups"] += len(groups)
                co["members"] += members
                if self.merge_across_requests and self.pad_batch_pow2:
                    co["padded_lanes"] += _next_pow2(members) - members
        results: dict[tuple[int, int], object] = {}
        for entries, handles, exc in inflight:
            if exc is None:
                try:
                    outs = [o for hd in handles for o in fetch_group_batch(hd)]
                    for (req, gi, _), out in zip(entries, outs):
                        results[(id(req), gi)] = out
                    continue
                except BaseException as fetch_exc:
                    exc = fetch_exc
            # the merged dispatch failed: isolate — re-run each request's
            # group alone so one poisoned member cannot fail its siblings.
            self._count_fault("dispatch_failures")
            safe_emit(self.tracker.event, "dispatch_failure",
                      error=repr(exc), members=len(entries))
            results.update(self._execute_isolated(entries))
        finished = []
        for req, groups in plans:
            if req not in active:
                continue
            outs = [results.get((id(req), gi)) for gi in range(len(groups))]
            errs = [o for o in outs if isinstance(o, BaseException)]
            if errs:
                active.remove(req)
                self._contain(req, errs[0])
                continue
            try:
                req.planner.advance(outs)
                if not req.planner.plan():
                    finished.append(req)
            except BaseException as exc:
                active.remove(req)
                self._contain(req, exc)
        for req in finished:
            active.remove(req)
            # finalize (evaluate_J, cache insert, future resolution) on the
            # worker pool: it overlaps the next levels' dispatches instead
            # of serializing behind them in the scheduler thread.
            self._fallback.submit(self._finalize_job, req, req.planner.result())

    def _execute_isolated(self, entries) -> dict:
        """Solo re-execution of each (request, group) from a failed merged
        dispatch, with transient-failure retries. Maps (id(req), gi) to a
        result array or the terminal exception."""
        with self._lock:
            self.telemetry["faults"]["isolated"] += len(entries)
        out: dict[tuple[int, int], object] = {}
        for (req, gi, gr) in entries:
            try:
                out[(id(req), gi)] = self._execute_with_retry(
                    gr, deadline=req.deadline)
            except BaseException as exc:
                out[(id(req), gi)] = exc
        return out

    def _execute_with_retry(self, gr: PlanGroup,
                            deadline: float | None = None) -> np.ndarray:
        """One group's dispatch with the retry policy: transient failures
        back off exponentially up to ``retry.max_retries``; deterministic
        failures raise immediately (retrying them cannot help).

        Each backoff sleep is capped at the request's remaining deadline
        budget and the deadline is re-checked before re-dispatching, so a
        retrying request can never resolve LATE — it fails with
        ``DeadlineExceededError`` the moment the budget runs out.
        """
        attempt = 0
        while True:
            try:
                self.faults.check("dispatch")
                return execute_group_batch(
                    [gr], self.telemetry["compile_cache"])[0]
            except BaseException as exc:
                if not self.retry.is_transient(exc) \
                        or attempt >= self.retry.max_retries:
                    raise
                backoff = self.retry.backoff_s(attempt, deadline=deadline)
                self._count_fault("retries")
                safe_emit(self.tracker.count, "service.retry")
                safe_emit(self.tracker.event, "retry", attempt=attempt,
                          backoff_s=backoff, error=repr(exc))
                time.sleep(backoff)
                if deadline is not None and time.monotonic() > deadline:
                    raise DeadlineExceededError(
                        "deadline exceeded during retry backoff") from exc
                attempt += 1

    # ------------------------------------------------- fallback / finalize

    def _run_fallback(self, req: _Request) -> None:
        attempt = 0
        while True:
            try:
                self._planner_checkpoint(req)  # deadline/abort before start
                self.faults.check("dispatch")
                res = capi.shared_map_direct(
                    req.g, req.h, req.cfg,
                    checkpoint=lambda: self._planner_checkpoint(req))
                self._resolve(req, res)
                return
            except BaseException as exc:
                if isinstance(exc, (DeadlineExceededError,
                                    ServiceClosedError)):
                    self._contain(req, exc)
                    return
                if self.retry.is_transient(exc) \
                        and attempt < self.retry.max_retries:
                    self._count_fault("retries")
                    safe_emit(self.tracker.count, "service.retry")
                    # capped at the deadline budget; the loop's checkpoint
                    # turns an exhausted budget into DeadlineExceededError
                    # before any re-dispatch.
                    time.sleep(self.retry.backoff_s(attempt,
                                                    deadline=req.deadline))
                    attempt += 1
                    continue
                self._contain(req, exc)
                return

    def _finalize_job(self, req: _Request, ms_result) -> None:
        try:
            self.faults.check("finalize")
            self._finalize(req, ms_result)
        except BaseException as exc:
            self._contain(req, exc)

    def _finalize(self, req: _Request, ms_result) -> None:
        pe_of = capi.finalize_mapping(req.g, req.h, req.cfg,
                                      ms_result.pe_of, ms_result.stats)
        res = SharedMapResult(pe_of=pe_of,
                              J=evaluate_J(req.g, req.h, pe_of),
                              stats=ms_result.stats)
        self._resolve(req, res)
        self._maybe_shadow(req, res)

    # ------------------------------------------------- supervised workers

    def _submit_to_worker(self, req: _Request) -> None:
        """Ship one request to the supervised worker pool as plain arrays
        (real CSR slices — padding is rebuilt worker-side). The deadline
        crosses the process boundary as a REMAINING duration: monotonic
        instants are not comparable between processes."""
        n, m = int(req.g.n), int(req.g.m)
        timeout_s = None
        if req.deadline is not None:
            timeout_s = max(req.deadline - time.monotonic(), 0.0)
        payload = {
            "vwgt": np.asarray(req.g.vwgt)[:n],
            "rows": np.asarray(req.g.rows)[:m],
            "cols": np.asarray(req.g.cols)[:m],
            "ewgt": np.asarray(req.g.ewgt)[:m],
            "n": n, "N": int(req.g.N), "M": int(req.g.M),
            "a": tuple(req.h.a), "d": tuple(req.h.d),
            "cfg": dataclasses.asdict(req.cfg),
            "timeout_s": timeout_s,
            "resident": self._resident_override(req.cfg),
        }
        try:
            fut = self.supervisor.submit(
                "repro.serve.supervisor:mapping_task", payload)
        except BaseException as exc:
            self._fail(req, exc)
            return
        fut.add_done_callback(
            lambda f, req=req: self._worker_done(req, f))

    def _worker_done(self, req: _Request, fut: Future) -> None:
        """Worker completion (runs on the supervisor's collector thread).
        Crash errors are transient (``WorkerCrashError.transient``) and
        fall into the normal containment/degradation ladder."""
        try:
            out = fut.result()
        except BaseException as exc:
            self._contain(req, exc)
            return
        try:
            if req.deadline is not None and time.monotonic() > req.deadline:
                self._deadline_miss(req)
                return
            res = SharedMapResult(pe_of=np.asarray(out["pe_of"]),
                                  J=float(out["J"]),
                                  stats=dict(out["stats"]))
            self._resolve(req, res)
            self._maybe_shadow(req, res)
        except BaseException as exc:
            self._fail(req, exc)

    # ---------------------------------------------------- shadow verification

    def _maybe_shadow(self, req: _Request, res: SharedMapResult) -> None:
        """Deterministically sample device-strategy results for re-execution
        against the bitwise host-ref twin (``resident=False``)."""
        if (self.shadow_verify_fraction <= 0.0
                or req.cfg.strategy != "device"
                or self._device_quarantined
                or req.degradation is not None):
            return
        with self._lock:
            self._shadow_seq += 1
            draw = _hash_uniform(getattr(self.faults, "seed", 0) or 0,
                                 "shadow", self._shadow_seq - 1)
        if draw >= self.shadow_verify_fraction:
            return
        try:
            self._fallback.submit(self._shadow_verify, req, res)
        except RuntimeError:
            # pool already shutting down (close raced the sampling): verify
            # inline so a sampled result is never silently dropped.
            self._shadow_verify(req, res)

    def _shadow_verify(self, req: _Request, res: SharedMapResult) -> None:
        """Re-execute on the host-ref twin and compare bitwise. Runs on the
        fallback pool AFTER the caller's Future resolved — verification
        costs latency only for the sampled fraction's *successors* (the
        quarantine decision), never for the sampled request itself."""
        with self._lock:
            self.telemetry["shadow"]["sampled"] += 1
        try:
            ref = capi.shared_map_direct(req.g, req.h, req.cfg,
                                         resident=False)
        except BaseException as exc:  # the twin failing is not a divergence
            safe_emit(self.tracker.event, "shadow_error", error=repr(exc))
            return
        if np.array_equal(np.asarray(res.pe_of), np.asarray(ref.pe_of)):
            with self._lock:
                self.telemetry["shadow"]["matched"] += 1
            safe_emit(self.tracker.count, "service.shadow.match")
            return
        self._shadow_mismatch(req, ref)

    def _shadow_mismatch(self, req: _Request, ref: SharedMapResult) -> None:
        """First divergence: quarantine the device strategy for the session,
        evict + quarantine the lying entry, re-cache the trusted host
        result under the same fingerprint."""
        with self._lock:
            self.telemetry["shadow"]["mismatched"] += 1
            self._device_quarantined = True
            self._cache.pop(req.fp, None)
            if self._by_graph.get(req.gfp) == req.fp:
                self._by_graph.pop(req.gfp, None)
        safe_emit(self.tracker.count, "service.shadow.mismatch")
        safe_emit(self.tracker.event, "shadow_mismatch", fp=req.fp.hex(),
                  strategy_quarantined="device")
        if self.store is not None:
            self.store.quarantine(req.fp, reason="shadow_mismatch")
        self._cache_put(req.fp, req.gfp, ref)

    # -------------------------------------------- containment / degradation

    def _contain(self, req: _Request, exc: BaseException) -> None:
        """Terminal failure handler for one request: degrade transient
        failures down the quality ladder (when enabled), propagate typed
        errors for everything else. Never raises."""
        if isinstance(exc, (DeadlineExceededError, ServiceClosedError)):
            self._fail(req, exc)
            return
        self._count_fault("contained")
        if self.degrade_on_failure and self.retry.is_transient(exc):
            self._fallback.submit(self._run_degraded, req, exc)
            return
        self._fail(req, exc)

    def _run_degraded(self, req: _Request, cause: BaseException) -> None:
        """Serve ``req`` down the quality ladder after its full-quality
        pipeline failed: cached-nearby → fast preset → greedy floor."""
        try:
            res = self._nearby_cached(req.gfp)
            if res is not None:
                self._resolve_degraded(req, res, DEGRADE_CACHED_NEARBY,
                                       "cached_nearby", cause)
                return
            if req.cfg.preset != "fast":
                try:
                    self.faults.check("dispatch")
                    res = capi.shared_map_direct(
                        req.g, req.h,
                        dataclasses.replace(req.cfg, preset="fast"),
                        checkpoint=lambda: self._planner_checkpoint(req))
                    self._resolve_degraded(req, res, DEGRADE_FAST_PRESET,
                                           "fast_preset", cause)
                    return
                except (DeadlineExceededError, ServiceClosedError) as exc:
                    self._fail(req, exc)
                    return
                except BaseException:
                    pass  # keep falling down the ladder
            pe_of = greedy_baseline(req.g, req.h, seed=req.cfg.seed)
            res = SharedMapResult(
                pe_of=pe_of, J=evaluate_J(req.g, req.h, pe_of),
                stats={"strategy": "greedy_baseline",
                       "backend": resolve_backend(req.cfg.backend)})
            self._resolve_degraded(req, res, DEGRADE_GREEDY, "greedy", cause)
        except BaseException as exc:  # even the floor failed: typed error out
            self._fail(req, exc)

    def _resolve_degraded(self, req: _Request, res: SharedMapResult,
                          level: int, mode: str,
                          cause: BaseException) -> None:
        req.degradation = {"level": level, "mode": mode, "reason": "failure",
                           "cause": repr(cause)}
        self.admission.note_degraded()
        self._count_fault("degraded")
        safe_emit(self.tracker.count, "service.degraded", mode=mode)
        safe_emit(self.tracker.event, "degraded", mode=mode,
                  cause=repr(cause))
        # degraded answers are never cached: a later identical request must
        # get the full-quality result, not a frozen emergency one.
        self._resolve(req, res, cache=False)

    def _serve_inline_degraded(self, g, h, cfg, fut: Future,
                               reason: str, tg=None) -> Future:
        """Hard-overload degradation, answered in the caller's thread (no
        queue slot consumed): cached-nearby if available, else the greedy
        floor — both cost microseconds. Caller holds ``_cv``."""
        adm = self.admission
        adm.note_degraded()
        self._count_fault("degraded")
        res = self._nearby_cached(graph_fingerprint(g, h, tg))
        if res is not None:
            level, mode = DEGRADE_CACHED_NEARBY, "cached_nearby"
        else:
            pe_of = greedy_baseline(g, h, seed=cfg.seed)
            res = SharedMapResult(
                pe_of=pe_of, J=evaluate_J(g, h, pe_of),
                stats={"strategy": "greedy_baseline",
                       "backend": resolve_backend(cfg.backend)})
            level, mode = DEGRADE_GREEDY, "greedy"
        safe_emit(self.tracker.count, "service.degraded", mode=mode)
        safe_emit(self.tracker.event, "degraded", mode=mode, reason=reason)
        fut.set_result(self._result_copy(
            res, cache_hit=(level == DEGRADE_CACHED_NEARBY),
            degradation={"level": level, "mode": mode, "reason": reason}))
        return fut

    def _deadline_miss(self, req: _Request) -> None:
        self._count_deadline_miss()
        self._fail(req, DeadlineExceededError(
            "deadline exceeded before the mapping completed"))

    def _count_deadline_miss(self) -> None:
        with self._cv:
            self.admission.note_deadline_miss()
        safe_emit(self.tracker.count, "service.deadline_miss")

    def _count_fault(self, name: str) -> None:
        with self._lock:
            self.telemetry["faults"][name] += 1

    # ------------------------------------------------------- future plumbing

    def _resolve(self, req: _Request, res: SharedMapResult,
                 cache: bool = True) -> None:
        if cache:
            self._cache_put(req.fp, req.gfp, res)
        self._finish_bookkeeping(req)
        for fut in req.futures:
            if not fut.done():  # a caller may have cancelled its Future
                fut.set_result(self._result_copy(
                    res, cache_hit=False, degradation=req.degradation))

    def _fail(self, req: _Request, exc: BaseException) -> None:
        self._finish_bookkeeping(req)
        for fut in req.futures:
            if not fut.done():
                fut.set_exception(exc)

    def _finish_bookkeeping(self, req: _Request) -> None:
        with self._cv:
            self._pending.pop(req.fp, None)
            if req.started:
                req.started = False
                self.admission.note_done()
            self._cv.notify_all()  # capacity freed: wake the scheduler

    # ---------------------------------------------------------- result cache

    def _cache_get(self, fp: bytes) -> SharedMapResult | None:
        if self.cache_entries <= 0 and self.store is None:
            return None
        try:
            self.faults.check("cache")
        except BaseException:  # contained: an injected cache fault = a miss
            self._count_fault("cache_faults")
            return None
        with self._lock:
            res = self._cache.get(fp)
            if res is not None:
                self._cache.move_to_end(fp)
                self.telemetry["requests"] += 1
                self.telemetry["result_cache"]["hits"] += 1
        if res is None and self.store is not None:
            # LRU miss: fall through to the persistence tier. The store
            # verifies the checksum — a corrupt entry is quarantined store-
            # side and surfaces here as a plain miss, never as a result.
            loaded = self.store.get(fp)
            if loaded is not None:
                res, gfp = loaded
                self._cache_insert(fp, gfp, res)
                with self._lock:
                    self.telemetry["requests"] += 1
                    self.telemetry["result_cache"]["hits"] += 1
                safe_emit(self.tracker.count, "service.store.hit")
        if res is not None:
            safe_emit(self.tracker.count, "service.cache.hit")
        return res

    def _cache_put(self, fp: bytes, gfp: bytes, res: SharedMapResult) -> None:
        if self.cache_entries <= 0 and self.store is None:
            return
        try:
            self.faults.check("cache")
        except BaseException:  # contained: the request still resolves
            self._count_fault("cache_faults")
            return
        self._cache_insert(fp, gfp, res)
        if self.store is not None:
            # persistence is a tier, not a requirement: put() swallows I/O
            # errors (counted in stats["store"]["write_errors"]).
            self.store.put(fp, gfp, res)

    def _cache_insert(self, fp: bytes, gfp: bytes,
                      res: SharedMapResult) -> None:
        """LRU insert only (no persistence side effects)."""
        if self.cache_entries <= 0:
            return
        with self._lock:
            self._cache[fp] = res
            self._cache.move_to_end(fp)
            self._by_graph[gfp] = fp
            while len(self._cache) > self.cache_entries:
                self._cache.popitem(last=False)
                self.telemetry["result_cache"]["evictions"] += 1
                safe_emit(self.tracker.count, "service.cache.eviction")

    def _nearby_cached(self, gfp: bytes) -> SharedMapResult | None:
        """Freshest cached result for the same (graph, hierarchy) under ANY
        config — step 1 of the degradation ladder."""
        with self._lock:
            fp = self._by_graph.get(gfp)
            if fp is None:
                return None
            res = self._cache.get(fp)
            if res is None:  # the entry was evicted; drop the dangling index
                self._by_graph.pop(gfp, None)
            return res

    def _result_copy(self, res: SharedMapResult, cache_hit: bool,
                     degradation: dict | None = None) -> SharedMapResult:
        """Fresh result per caller: private pe_of, stats annotated with the
        service telemetry (the compute stats themselves are shared refs on
        cache hits — treat them as read-only)."""
        with self._lock:
            rc = dict(self.telemetry["result_cache"])
        rc["hit"] = cache_hit
        stats = dict(res.stats)
        stats["result_cache"] = rc
        stats["service"] = {"merge_across_requests": self.merge_across_requests,
                            "pad_batch_pow2": self.pad_batch_pow2}
        stats["degradation"] = degradation or {"level": DEGRADE_FULL,
                                               "mode": "full", "reason": ""}
        return SharedMapResult(pe_of=res.pe_of.copy(), J=res.J, stats=stats)
