"""Batched, cached mapping service (DESIGN.md §9).

Turns the one-shot ``shared_map`` entry point into a long-lived service for
heavy mapping traffic. Three mechanisms, all bit-transparent to callers:

* **Cross-request coalescing** — every in-flight request runs on a
  ``core.multisection.LevelPlanner``; a single scheduler thread gathers the
  per-level :class:`PlanGroup`s of ALL active planners, merges groups with
  equal ``exec_key`` and dispatches each merged set as ONE stacked vmapped
  ``_batched_partition`` call. vmap lanes are independent, so each
  request's result is bit-identical to the direct path (tested) while the
  per-dispatch overheads (Python jit dispatch, host stacking, transfers,
  device sync) are paid once per shape instead of once per request.
* **Content-addressed result cache** — requests are fingerprinted by their
  real CSR arrays + hierarchy vector + config; repeats are answered from
  an LRU cache in microseconds. Concurrent identical requests dedup onto
  one in-flight computation.
* **Warmup** — :meth:`MappingService.warmup` pre-populates the process's
  jit/program cache for the expected bucket shapes so first-request
  latency is predictable instead of compile-bound.

Usage::

    svc = MappingService()
    with svc.installed():          # route shared_map through the service
        res = shared_map(g, h)     # coalesced + cached transparently
    # or explicitly:
    fut = svc.submit(g, h, cfg)    # concurrent.futures.Future
    res = await svc.amap(g, h)     # asyncio
    svc.close()

The non-plannable strategies (``naive``/``queue``) fall back to the direct
path on a small worker pool — still cached, never coalesced.
"""
from __future__ import annotations

import asyncio
import dataclasses
import hashlib
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from contextlib import contextmanager

import numpy as np

from repro.core import api as capi
from repro.core.api import SharedMapConfig, SharedMapResult
from repro.core.graph import Graph, from_edges
from repro.core.hierarchy import Hierarchy
from repro.core.mapping import evaluate_J
from repro.core.multisection import (LevelPlanner, PlanGroup, _ell_deg_for,
                                     _next_pow2, dispatch_group_batch,
                                     execute_group_batch, fetch_group_batch,
                                     host_graph_from)
from repro.core.partition import num_levels
from repro.core.refine import resolve_backend

_PLANNABLE = ("bucket", "layer")


def request_fingerprint(g: Graph, h: Hierarchy, cfg: SharedMapConfig) -> bytes:
    """Content address of a mapping request: the REAL CSR arrays (padding
    never affects planning — the planner re-pads from real sizes), the
    hierarchy vectors and every config field that influences the result.
    ``backend`` enters resolved, so auto/xla hit the same entry off-TPU."""
    n = int(g.n)
    m = int(g.m)
    hs = hashlib.blake2b(digest_size=16)
    for arr in (np.asarray(g.vwgt)[:n], np.asarray(g.rows)[:m],
                np.asarray(g.cols)[:m], np.asarray(g.ewgt)[:m]):
        a = np.ascontiguousarray(arr)
        hs.update(str(a.dtype).encode())
        hs.update(a.tobytes())
    hs.update(repr((n, m, tuple(h.a), tuple(h.d), float(cfg.eps), cfg.preset,
                    cfg.strategy, int(cfg.seed), bool(cfg.adaptive),
                    resolve_backend(cfg.backend),
                    bool(cfg.refine_mapping))).encode())
    return hs.digest()


@dataclasses.dataclass
class _Request:
    g: Graph
    h: Hierarchy
    cfg: SharedMapConfig
    fp: bytes
    futures: list[Future]
    planner: LevelPlanner | None = None


def _dummy_host_graph(N: int, M: int):
    """A path graph filling the (N, M) padded shape, for warmup compiles."""
    if N < 2 or M < 2:
        raise ValueError(f"warmup shape too small: N={N}, M={M}")
    e = max(min(N - 1, M // 2), 1)
    u = np.arange(e, dtype=np.int64)
    return host_graph_from(from_edges(N, u, u + 1, N=N, M=M))


class MappingService:
    """Async mapping service: concurrent ``(Graph, Hierarchy, config)``
    requests, coalesced dispatches, LRU result cache, warmup.

    Parameters
    ----------
    cache_entries: LRU bound of the result cache (0 disables caching).
    batch_window_s: how long the scheduler waits after a request arrives
        on an idle service before planning, so a concurrent burst lands in
        the same coalesced dispatches. In-flight requests always coalesce
        regardless of the window.
    merge_across_requests: dispatch same-``exec_key`` groups of different
        requests as one batch (False = per-request dispatches; the service
        then only adds caching and the async front).
    pad_batch_pow2: pad merged batches to the next power of two (spare
        lanes replicate the last member and are dropped) so XLA compiles
        O(log B) batch widths per shape instead of one per distinct B —
        the knob that makes :meth:`warmup` coverage feasible.
    fallback_workers: thread pool size for the non-plannable strategies.
    """

    def __init__(self, cache_entries: int = 256, batch_window_s: float = 0.002,
                 merge_across_requests: bool = True, pad_batch_pow2: bool = True,
                 fallback_workers: int = 2):
        self.cache_entries = int(cache_entries)
        self.batch_window_s = float(batch_window_s)
        self.merge_across_requests = bool(merge_across_requests)
        self.pad_batch_pow2 = bool(pad_batch_pow2)
        self._cv = threading.Condition()
        self._queue: list[_Request] = []
        self._pending: dict[bytes, _Request] = {}  # queued + active, by fp
        self._closed = False
        self._thread: threading.Thread | None = None
        self._fallback = ThreadPoolExecutor(
            max_workers=max(1, fallback_workers),
            thread_name_prefix="mapper-fallback")
        self._cache: OrderedDict[bytes, SharedMapResult] = OrderedDict()
        self._lock = threading.Lock()  # cache + telemetry
        self.telemetry = {
            "requests": 0,
            "inflight_dedup": 0,
            "result_cache": {"hits": 0, "misses": 0, "evictions": 0},
            "coalesce": {"dispatches": 0, "groups": 0, "members": 0,
                         "padded_lanes": 0},
            "compile_cache": {"hits": 0, "misses": 0},
            "warmup": {"programs": 0, "seconds": 0.0},
        }

    # ------------------------------------------------------------- frontend

    def submit(self, g: Graph, h: Hierarchy,
               config: SharedMapConfig | None = None) -> Future:
        """Enqueue a mapping request; returns a Future[SharedMapResult]."""
        cfg = config or SharedMapConfig()
        fp = request_fingerprint(g, h, cfg)
        fut: Future = Future()
        cached = self._cache_get(fp)
        if cached is not None:
            fut.set_result(self._result_copy(cached, cache_hit=True))
            return fut
        with self._lock:
            self.telemetry["requests"] += 1
            self.telemetry["result_cache"]["misses"] += 1
        with self._cv:
            if self._closed:
                raise RuntimeError("MappingService is closed")
            inflight = self._pending.get(fp)
            if inflight is not None:
                # identical request already queued/active: one computation
                inflight.futures.append(fut)
                with self._lock:
                    self.telemetry["inflight_dedup"] += 1
                return fut
            req = _Request(g=g, h=h, cfg=cfg, fp=fp, futures=[fut])
            self._pending[fp] = req
            self._queue.append(req)
            self._ensure_thread()
            self._cv.notify_all()
        return fut

    def submit_many(self, requests) -> list[Future]:
        """Atomically enqueue a burst of ``(g, h, config)`` requests.

        All of them are admitted in ONE scheduler iteration, so the merged
        batch compositions (and therefore the compiled batch widths) are
        deterministic for a given burst — independent of caller timing.
        """
        with self._cv:  # Condition wraps an RLock: nested submit is fine
            futs = [self.submit(g, h, cfg) for (g, h, cfg) in requests]
        return futs

    def map(self, g: Graph, h: Hierarchy,
            config: SharedMapConfig | None = None) -> SharedMapResult:
        """Blocking request (the ``shared_map`` route when installed)."""
        return self.submit(g, h, config).result()

    async def amap(self, g: Graph, h: Hierarchy,
                   config: SharedMapConfig | None = None) -> SharedMapResult:
        """Asyncio request."""
        return await asyncio.wrap_future(self.submit(g, h, config))

    # -------------------------------------------------------------- warmup

    def warmup(self, shapes, ks, preset: str = "eco", backend: str = "auto",
               eps: float = 0.03, batch_sizes=(1, 2, 4, 8),
               ell_degs=None) -> dict:
        """Pre-compile the bucket executables for the expected traffic.

        ``shapes``: (N, M) padded bucket shapes (powers of two, as the
        bucket scheduler produces); ``ks``: sub-partition arities;
        ``batch_sizes``: coalesced batch widths to cover (the service pads
        merged batches to powers of two by default, so a handful of widths
        covers all traffic). ``ell_degs`` optionally pins the static ELL
        degree caps to warm for the kernel backend (default: derived from
        the dummy graph, which is what xla — the common case — ignores).
        """
        backend = resolve_backend(backend)
        t0 = time.time()
        programs = 0
        for (N, M) in shapes:
            hg = _dummy_host_graph(int(N), int(M))
            degs = tuple(ell_degs) if ell_degs is not None \
                else (_ell_deg_for([hg], backend),)
            for k in ks:
                lv = num_levels(int(N), int(k))
                for deg in degs:
                    for B in batch_sizes:
                        gr = PlanGroup(
                            members=[hg] * int(B), N=int(N), M=int(M),
                            arity=int(k), levels=lv, preset=preset,
                            backend=backend, deg=deg,
                            eps=[float(eps)] * int(B),
                            salts=list(range(int(B))))
                        execute_group_batch([gr], self.telemetry["compile_cache"])
                        programs += 1
        dt = time.time() - t0
        with self._lock:
            self.telemetry["warmup"]["programs"] += programs
            self.telemetry["warmup"]["seconds"] += dt
        return {"programs": programs, "seconds": dt}

    # ---------------------------------------------------------- install / cm

    def install(self) -> "MappingService":
        """Route ``core.api.shared_map`` through this service."""
        capi.install_service(self)
        return self

    def uninstall(self) -> None:
        if capi.current_service() is self:
            capi.install_service(None)

    @contextmanager
    def installed(self):
        prev = capi.install_service(self)
        try:
            yield self
        finally:
            capi.install_service(prev)

    def close(self, wait: bool = True) -> None:
        """Drain in-flight requests and stop the scheduler."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if self._thread is not None and wait:
            self._thread.join()
        self._fallback.shutdown(wait=wait)
        self.uninstall()

    def __enter__(self) -> "MappingService":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.uninstall()
        self.close()

    def stats(self) -> dict:
        """Snapshot of the service telemetry."""
        with self._lock:
            snap = {k: (dict(v) if isinstance(v, dict) else v)
                    for k, v in self.telemetry.items()}
            snap["result_cache"]["entries"] = len(self._cache)
            snap["result_cache"]["capacity"] = self.cache_entries
        return snap

    # ------------------------------------------------------------ scheduler

    def _ensure_thread(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(target=self._loop, daemon=True,
                                            name="mapper-scheduler")
            self._thread.start()

    def _loop(self) -> None:
        active: list[_Request] = []
        while True:
            with self._cv:
                while not self._queue and not active and not self._closed:
                    self._cv.wait()
                if self._closed and not self._queue and not active:
                    return
                newly, self._queue = self._queue, []
            if newly and not active and self.batch_window_s > 0:
                # idle service: hold the first arrivals briefly so a
                # concurrent burst coalesces from level 0 on.
                time.sleep(self.batch_window_s)
                with self._cv:
                    newly += self._queue
                    self._queue = []
            for req in newly:
                try:
                    self._admit(req, active)
                except BaseException as exc:  # fail fast, never hang callers
                    self._fail(req, exc)
            try:
                if active:
                    self._step(active)
            except BaseException as exc:
                for req in active:
                    self._fail(req, exc)
                active = []

    def _admit(self, req: _Request, active: list[_Request]) -> None:
        if req.cfg.strategy in _PLANNABLE:
            try:
                req.planner = LevelPlanner(
                    req.g, req.h, eps=req.cfg.eps, preset=req.cfg.preset,
                    seed=req.cfg.seed, adaptive=req.cfg.adaptive,
                    backend=req.cfg.backend,
                    bucketed=(req.cfg.strategy == "bucket"))
            except BaseException as exc:
                self._fail(req, exc)
                return
            active.append(req)
        else:
            self._fallback.submit(self._run_fallback, req)

    def _step(self, active: list[_Request]) -> None:
        """One coalesced execution round over all active planners."""
        plans = [(req, req.planner.plan()) for req in active]
        merged: OrderedDict[tuple, list[tuple[_Request, int, PlanGroup]]] = \
            OrderedDict()
        for req, groups in plans:
            for gi, gr in enumerate(groups):
                merged.setdefault(gr.exec_key, []).append((req, gi, gr))
        # dispatch ALL merged sets before fetching any: XLA dispatch is
        # async, so stacking set k+1 overlaps device compute of set k.
        inflight = []
        for entries in merged.values():
            groups = [e[2] for e in entries]
            if self.merge_across_requests:
                handles = [dispatch_group_batch(
                    groups, self.telemetry["compile_cache"],
                    pad_batch_pow2=self.pad_batch_pow2)]
                dispatches = 1
            else:
                handles = [dispatch_group_batch(
                    [gr], self.telemetry["compile_cache"]) for gr in groups]
                dispatches = len(groups)
            inflight.append((entries, handles))
            members = sum(len(gr.members) for gr in groups)
            with self._lock:
                co = self.telemetry["coalesce"]
                co["dispatches"] += dispatches
                co["groups"] += len(groups)
                co["members"] += members
                if self.merge_across_requests and self.pad_batch_pow2:
                    co["padded_lanes"] += _next_pow2(members) - members
        results: dict[tuple[int, int], np.ndarray] = {}
        for entries, handles in inflight:
            outs = [o for hd in handles for o in fetch_group_batch(hd)]
            for (req, gi, _), out in zip(entries, outs):
                results[(id(req), gi)] = out
        finished = []
        for req, groups in plans:
            req.planner.advance([results[(id(req), gi)]
                                 for gi in range(len(groups))])
            if not req.planner.plan():
                finished.append(req)
        for req in finished:
            active.remove(req)
            # finalize (evaluate_J, cache insert, future resolution) on the
            # worker pool: it overlaps the next levels' dispatches instead
            # of serializing behind them in the scheduler thread.
            self._fallback.submit(self._finalize_job, req, req.planner.result())

    def _run_fallback(self, req: _Request) -> None:
        try:
            res = capi.shared_map_direct(req.g, req.h, req.cfg)
            self._resolve(req, res)
        except BaseException as exc:
            self._fail(req, exc)

    def _finalize_job(self, req: _Request, ms_result) -> None:
        try:
            self._finalize(req, ms_result)
        except BaseException as exc:
            self._fail(req, exc)

    def _finalize(self, req: _Request, ms_result) -> None:
        pe_of = capi.finalize_mapping(req.g, req.h, req.cfg,
                                      ms_result.pe_of, ms_result.stats)
        res = SharedMapResult(pe_of=pe_of,
                              J=evaluate_J(req.g, req.h, pe_of),
                              stats=ms_result.stats)
        self._resolve(req, res)

    def _resolve(self, req: _Request, res: SharedMapResult) -> None:
        self._cache_put(req.fp, res)
        with self._cv:
            self._pending.pop(req.fp, None)
        for fut in req.futures:
            if not fut.done():  # a caller may have cancelled its Future
                fut.set_result(self._result_copy(res, cache_hit=False))

    def _fail(self, req: _Request, exc: BaseException) -> None:
        with self._cv:
            self._pending.pop(req.fp, None)
        for fut in req.futures:
            if not fut.done():
                fut.set_exception(exc)

    # ---------------------------------------------------------- result cache

    def _cache_get(self, fp: bytes) -> SharedMapResult | None:
        if self.cache_entries <= 0:
            return None
        with self._lock:
            res = self._cache.get(fp)
            if res is not None:
                self._cache.move_to_end(fp)
                self.telemetry["requests"] += 1
                self.telemetry["result_cache"]["hits"] += 1
            return res

    def _cache_put(self, fp: bytes, res: SharedMapResult) -> None:
        if self.cache_entries <= 0:
            return
        with self._lock:
            self._cache[fp] = res
            self._cache.move_to_end(fp)
            while len(self._cache) > self.cache_entries:
                self._cache.popitem(last=False)
                self.telemetry["result_cache"]["evictions"] += 1

    def _result_copy(self, res: SharedMapResult, cache_hit: bool) -> SharedMapResult:
        """Fresh result per caller: private pe_of, stats annotated with the
        service telemetry (the compute stats themselves are shared refs on
        cache hits — treat them as read-only)."""
        with self._lock:
            rc = dict(self.telemetry["result_cache"])
        rc["hit"] = cache_hit
        stats = dict(res.stats)
        stats["result_cache"] = rc
        stats["service"] = {"merge_across_requests": self.merge_across_requests,
                            "pad_batch_pow2": self.pad_batch_pow2}
        return SharedMapResult(pe_of=res.pe_of.copy(), J=res.J, stats=stats)
