"""Crash-safe, content-addressed persistent result store (DESIGN.md §12).

PR 5's result cache is an in-process LRU: it dies with the process, so a
restarted mapping service re-pays every compute it had already done — the
ROADMAP's "cache persistence shared across worker processes" item. This
module is the durability tier behind that LRU:

* **Content-addressed** — entries are keyed by the request fingerprint
  (``serve/mapper.request_fingerprint``: real CSR arrays + hierarchy +
  config), so a reload can only ever serve the bit-identical result the
  same request would recompute.
* **Crash-safe writes** — each entry is serialized to a private temp file
  and published with an atomic ``os.replace``: readers (including other
  processes sharing the directory) see either the complete entry or no
  entry, never a torn one. A crash mid-write leaves only a stale temp
  file, swept opportunistically.
* **Self-verifying entries** — every entry carries a 4-byte magic, a
  schema version, and a blake2b-128 checksum over the full body (header +
  payload). Truncated, bit-flipped, or wrong-version entries are detected
  on load, moved to a ``quarantine/`` subdirectory (never deleted — they
  are forensic evidence), counted in ``stats()["corrupt"]`` and NEVER
  returned to the caller: a corrupt store degrades to a cache miss, not to
  wrong answers.
* **Deterministic fault injection** — a ``repro.faults.FaultInjector``
  checked at the ``store_write`` seam simulates a torn write (the entry is
  deliberately truncated mid-body but still atomically published), so the
  corruption-detection path is exercised end-to-end in tests without
  touching real disk failure machinery.

Entry format (version 1)::

    [0:4)   magic  b"RST1"
    [4:20)  blake2b-16 digest of body
    [20:)   body = u32 header_len | header JSON (utf-8) | pe_of raw bytes

The header JSON carries the schema version, the fingerprint, the graph
fingerprint (to rebuild the service's nearby-result index), dtype/shape of
``pe_of``, ``J``, and the compute ``stats`` dict. The checksum is verified
BEFORE any parsing, so corrupt bytes never reach the JSON or numpy layer.
"""
from __future__ import annotations

import json
import logging
import os
import struct
import threading

import numpy as np

from repro.core.api import SharedMapResult
from repro.faults import NULL_INJECTOR, FaultInjector

_MAGIC = b"RST1"
_DIGEST_SIZE = 16
_SCHEMA_VERSION = 1
_HDR = struct.Struct("<I")  # body prefix: header length

log = logging.getLogger(__name__)


def _blake(data: bytes) -> bytes:
    import hashlib
    return hashlib.blake2b(data, digest_size=_DIGEST_SIZE).digest()


def _json_default(o):
    """Stats dicts may carry numpy scalars/arrays; store plain values."""
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    return str(o)


class CorruptEntryError(ValueError):
    """An entry failed verification (bad magic/version/checksum/shape)."""


def encode_entry(fp: bytes, gfp: bytes, res: SharedMapResult) -> bytes:
    """Serialize one result into the self-verifying entry format."""
    pe = np.ascontiguousarray(np.asarray(res.pe_of))
    header = json.dumps({
        "v": _SCHEMA_VERSION,
        "fp": fp.hex(),
        "gfp": gfp.hex(),
        "dtype": str(pe.dtype),
        "shape": list(pe.shape),
        "J": float(res.J),
        "stats": res.stats,
    }, default=_json_default).encode()
    body = _HDR.pack(len(header)) + header + pe.tobytes()
    return _MAGIC + _blake(body) + body


def decode_entry(blob: bytes, fp: bytes) -> tuple[SharedMapResult, bytes]:
    """Verify + parse an entry blob; returns (result, graph fingerprint).

    Raises :class:`CorruptEntryError` on ANY inconsistency — truncation,
    bit flips, wrong magic, wrong schema version, or a fingerprint that
    does not match the file's name (a misfiled entry is as dangerous as a
    corrupt one: it would answer the wrong request).
    """
    base = len(_MAGIC) + _DIGEST_SIZE
    if len(blob) < base + _HDR.size:
        raise CorruptEntryError(f"entry truncated to {len(blob)} bytes")
    if blob[:len(_MAGIC)] != _MAGIC:
        raise CorruptEntryError(f"bad magic {blob[:len(_MAGIC)]!r}")
    digest = blob[len(_MAGIC):base]
    body = blob[base:]
    if _blake(body) != digest:
        raise CorruptEntryError("checksum mismatch (bit flip or torn write)")
    (hlen,) = _HDR.unpack_from(body)
    if len(body) < _HDR.size + hlen:
        raise CorruptEntryError("header truncated")
    try:
        header = json.loads(body[_HDR.size:_HDR.size + hlen])
    except ValueError as exc:  # checksum passed but JSON broken: impossible
        raise CorruptEntryError(f"unparseable header: {exc}") from exc
    if header.get("v") != _SCHEMA_VERSION:
        raise CorruptEntryError(f"schema version {header.get('v')!r} != "
                                f"{_SCHEMA_VERSION}")
    if header.get("fp") != fp.hex():
        raise CorruptEntryError("entry fingerprint does not match its key")
    dtype = np.dtype(header["dtype"])
    shape = tuple(int(s) for s in header["shape"])
    payload = body[_HDR.size + hlen:]
    expect = dtype.itemsize * int(np.prod(shape)) if shape else dtype.itemsize
    if len(payload) != expect:
        raise CorruptEntryError(f"payload is {len(payload)} bytes, "
                                f"expected {expect}")
    pe = np.frombuffer(payload, dtype=dtype).reshape(shape).copy()
    res = SharedMapResult(pe_of=pe, J=float(header["J"]),
                          stats=dict(header["stats"]))
    return res, bytes.fromhex(header.get("gfp", ""))


class ResultStore:
    """Directory-backed crash-safe result store.

    One file per entry (``<fp-hex>.res``), atomic publication, checksum
    verification on every read, quarantine of anything that fails it.
    Thread-safe; multiple processes may share a directory (writes are
    atomic renames, reads never observe partial files).

    Parameters
    ----------
    path: store directory (created, along with ``quarantine/``).
    fault_injector: checked at the ``store_write`` seam — a fired fault
        publishes a deliberately TRUNCATED entry (a simulated torn write)
        instead of failing the put, so corruption detection is testable.
    """

    def __init__(self, path: str,
                 fault_injector: FaultInjector = NULL_INJECTOR):
        self.path = str(path)
        self.quarantine_dir = os.path.join(self.path, "quarantine")
        self._tmp_dir = os.path.join(self.path, "tmp")
        self.faults = fault_injector
        self._lock = threading.Lock()
        self._seq = 0
        self._stats = {"hits": 0, "misses": 0, "writes": 0, "write_errors": 0,
                       "corrupt": 0, "quarantined": 0, "bytes_written": 0,
                       "entries_on_open": 0}
        os.makedirs(self.path, exist_ok=True)
        os.makedirs(self.quarantine_dir, exist_ok=True)
        os.makedirs(self._tmp_dir, exist_ok=True)
        self._sweep_tmp()
        self._stats["entries_on_open"] = len(self.keys())

    # ------------------------------------------------------------- paths

    def _entry_path(self, fp: bytes) -> str:
        return os.path.join(self.path, fp.hex() + ".res")

    def keys(self) -> list[bytes]:
        """Fingerprints of every published entry (no verification)."""
        out = []
        try:
            names = os.listdir(self.path)
        except OSError:
            return out
        for name in names:
            if name.endswith(".res"):
                try:
                    out.append(bytes.fromhex(name[:-4]))
                except ValueError:
                    pass  # foreign file; ignore
        return out

    def __len__(self) -> int:
        return len(self.keys())

    def _sweep_tmp(self) -> None:
        """Remove temp files orphaned by a crash mid-write: they were never
        published, so deleting them cannot lose a committed entry."""
        try:
            for name in os.listdir(self._tmp_dir):
                try:
                    os.unlink(os.path.join(self._tmp_dir, name))
                except OSError:
                    pass
        except OSError:
            pass

    # --------------------------------------------------------------- I/O

    def put(self, fp: bytes, gfp: bytes, res: SharedMapResult) -> bool:
        """Atomically publish ``res`` under ``fp``. Returns False (and
        counts ``write_errors``) on I/O failure — persistence is a tier,
        not a requirement: the serving path never fails on a store error."""
        try:
            blob = encode_entry(fp, gfp, res)
            try:
                self.faults.check("store_write")
            except BaseException:
                # injected torn write: publish a truncated body. Still an
                # ATOMIC rename — this models a crash between the write
                # syscalls of a non-atomic writer, which is exactly the
                # failure the checksum exists to catch.
                blob = blob[: max(len(blob) // 2, 1)]
            with self._lock:
                self._seq += 1
                tmp = os.path.join(self._tmp_dir,
                                   f"{fp.hex()}.{os.getpid()}.{self._seq}")
            with open(tmp, "wb") as f:
                f.write(blob)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self._entry_path(fp))
            with self._lock:
                self._stats["writes"] += 1
                self._stats["bytes_written"] += len(blob)
            return True
        except Exception:
            log.debug("result store write failed", exc_info=True)
            with self._lock:
                self._stats["write_errors"] += 1
            return False

    def get(self, fp: bytes) -> tuple[SharedMapResult, bytes] | None:
        """Load + verify the entry for ``fp``; ``(result, gfp)`` or None.

        A corrupt entry is quarantined and reported as a miss — it is
        NEVER returned.
        """
        path = self._entry_path(fp)
        try:
            with open(path, "rb") as f:
                blob = f.read()
        except FileNotFoundError:
            with self._lock:
                self._stats["misses"] += 1
            return None
        except OSError:
            log.debug("result store read failed", exc_info=True)
            with self._lock:
                self._stats["misses"] += 1
            return None
        try:
            res, gfp = decode_entry(blob, fp)
        except CorruptEntryError as exc:
            with self._lock:
                self._stats["corrupt"] += 1
            self.quarantine(fp, reason=str(exc))
            with self._lock:
                self._stats["misses"] += 1
            return None
        with self._lock:
            self._stats["hits"] += 1
        return res, gfp

    def quarantine(self, fp: bytes, reason: str = "") -> bool:
        """Move an entry out of the serving set into ``quarantine/`` (kept
        for forensics, with the reason alongside). Also the eviction path
        for entries the shadow verifier disowns."""
        src = self._entry_path(fp)
        dst = os.path.join(self.quarantine_dir, fp.hex() + ".res")
        try:
            os.replace(src, dst)
        except FileNotFoundError:
            return False
        except OSError:
            try:  # cross-device or permission trouble: removal still
                os.unlink(src)  # guarantees it can never be served
            except OSError:
                return False
        try:
            with open(dst + ".reason", "w") as f:
                f.write(reason + "\n")
        except OSError:
            pass
        with self._lock:
            self._stats["quarantined"] += 1
        log.warning("result store quarantined %s: %s", fp.hex(), reason)
        return True

    def stats(self) -> dict:
        with self._lock:
            snap = dict(self._stats)
        snap["entries"] = len(self)
        return snap
