"""Batched serving engine: prefill + decode with KV caches.

Container-scale real execution (examples/serve_lm.py) and the substrate the
``decode_*``/``long_*`` dry-run cells lower.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.sharding import ShardCtx


@dataclasses.dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens: int = 0

    @property
    def tok_per_s(self) -> float:
        return self.tokens / self.decode_s if self.decode_s else 0.0


class Engine:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 512,
                 ctx: ShardCtx | None = None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.ctx = ctx
        self._decode = jax.jit(
            lambda p, t, c, pos: M.decode_fn(cfg, p, t, c, pos, ctx),
            donate_argnums=(2,))

    def generate(self, prompts: np.ndarray, steps: int, temperature: float = 0.0,
                 seed: int = 0) -> tuple[np.ndarray, ServeStats]:
        """prompts [B, P] int32 -> generated [B, steps]."""
        cfg = self.cfg
        B, P = prompts.shape
        stats = ServeStats()
        cache = M.init_cache(cfg, B, self.max_len)
        key = jax.random.PRNGKey(seed)

        # prefill by stepping the decoder over the prompt (cache-exact; the
        # batched-prefill path is exercised by prefill_fn in the dry-run)
        t0 = time.time()
        logits = None
        for i in range(P):
            logits, cache = self._decode(self.params, jnp.asarray(prompts[:, i:i+1], jnp.int32),
                                         cache, jnp.asarray(i, jnp.int32))
        jax.block_until_ready(logits)
        stats.prefill_s = time.time() - t0

        out = []
        t0 = time.time()
        last = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        for i in range(steps):
            logits, cache = self._decode(self.params, last, cache,
                                         jnp.asarray(P + i, jnp.int32))
            if temperature > 0:
                key, sub = jax.random.split(key)
                last = jax.random.categorical(
                    sub, logits[:, -1].astype(jnp.float32) / temperature)[:, None].astype(jnp.int32)
            else:
                last = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            out.append(np.asarray(last))
        jax.block_until_ready(logits)
        stats.decode_s = time.time() - t0
        stats.tokens = B * steps
        return np.concatenate(out, axis=1), stats
