"""Admission control, deadlines, and retry policy for the mapping service.

The paper's serving premise is bursty traffic (mapping sits in the launch
critical path of jobs with up to millions of tasks); PR 5's service
accepted unbounded work. This module is the policy layer:

* :class:`AdmissionController` — bounded waiting queue + bounded in-flight
  set. Over the queue bound the service LOAD-SHEDS with an explicit
  :class:`ServiceOverloadError` instead of queueing silently; a
  higher-priority arrival may instead preempt the lowest-priority waiter
  (the victim is shed). A soft watermark (``degrade_at``) marks the
  "degrade instead of full quality" region below the hard bound — the
  serving-side analogue of the fast/eco/strong quality spectrum
  (arXiv 2001.07134).
* Deadline bookkeeping — requests carry an absolute monotonic deadline;
  expiry is checked at submit, at queue admission, and cooperatively
  between multisection levels (``LevelPlanner`` checkpoints), raising
  :class:`DeadlineExceededError`.
* :class:`RetryPolicy` — bounded retries with exponential backoff for
  *transient* dispatch failures (injected faults flagged transient,
  OOM/resource-exhausted style errors); deterministic errors are never
  retried, they isolate to the offending request.

The controller is passive bookkeeping: the service mutates it under its
own scheduler lock, so there is no second lock order to reason about.
"""
from __future__ import annotations

import dataclasses
import time


class ServiceOverloadError(RuntimeError):
    """Request shed by admission control (queue full / preempted).

    Carries the observed load so callers can implement client-side
    backoff; ``retry_after_s`` is a coarse hint, not a promise.
    """

    def __init__(self, message: str, queued: int = 0, inflight: int = 0,
                 retry_after_s: float | None = None):
        super().__init__(message)
        self.queued = queued
        self.inflight = inflight
        self.retry_after_s = retry_after_s


class DeadlineExceededError(TimeoutError):
    """Request cancelled past its deadline (queued or mid-pipeline)."""


class ServiceClosedError(RuntimeError):
    """Request rejected or abandoned because the service is shut down."""


# admission decisions (returned by AdmissionController.decide)
ADMIT = "admit"              # queue normally, full quality
ADMIT_DEGRADED = "degraded"  # queue, but serve along the quality ladder
PREEMPT = "preempt"          # queue full: shed the lowest-priority waiter
SHED = "shed"                # reject the newcomer


@dataclasses.dataclass
class AdmissionController:
    """Bounded-queue/bounded-inflight bookkeeping with priorities.

    ``max_queue`` bounds accepted-but-waiting requests, ``max_inflight``
    bounds how many the scheduler actively plans at once (backpressure:
    excess stays queued, overflow is shed). ``degrade_at`` is the soft
    watermark as a fraction of ``max_queue``: at or above it, new arrivals
    are admitted degraded (when the service enables degradation) so the
    service trades quality for survival before it starts shedding.
    """

    max_inflight: int = 16
    max_queue: int = 256
    degrade_at: float = 0.75

    def __post_init__(self):
        self.queued = 0
        self.inflight = 0
        self.counters = {"admitted": 0, "shed": 0, "preempted": 0,
                         "degraded": 0, "deadline_miss": 0}

    # -- decisions ---------------------------------------------------------

    def decide(self, priority: int, min_waiting_priority: int | None,
               degrade_ok: bool) -> str:
        """Admission decision for a newcomer with ``priority``.

        ``min_waiting_priority`` is the lowest priority currently waiting
        (None = nobody waits); a strictly higher-priority newcomer evicts
        that waiter when the queue is full.
        """
        if self.queued < self.hard_bound():
            if degrade_ok and self.queued >= self.soft_bound():
                return ADMIT_DEGRADED
            return ADMIT
        if min_waiting_priority is not None and priority > min_waiting_priority:
            return PREEMPT
        return SHED

    def hard_bound(self) -> int:
        return max(int(self.max_queue), 0)

    def soft_bound(self) -> int:
        """Queue depth at which degradation starts (clamped inside bounds)."""
        return max(min(int(self.degrade_at * self.max_queue),
                       self.hard_bound() - 1), 0)

    def overloaded(self) -> bool:
        return self.queued >= self.soft_bound() and self.queued > 0 \
            or self.hard_bound() == 0

    # -- state transitions (call under the service scheduler lock) ---------

    def note_queued(self) -> None:
        self.queued += 1
        self.counters["admitted"] += 1

    def note_degraded(self) -> None:
        """A request served along the quality ladder (queued or inline)."""
        self.counters["degraded"] += 1

    def note_dequeued(self) -> None:
        self.queued -= 1

    def note_start(self) -> None:
        self.inflight += 1

    def note_done(self) -> None:
        self.inflight -= 1

    def note_shed(self, preempted: bool = False) -> None:
        self.counters["preempted" if preempted else "shed"] += 1

    def note_deadline_miss(self) -> None:
        self.counters["deadline_miss"] += 1

    def has_capacity(self) -> bool:
        """Room for another active planner (the scheduler's gate)."""
        return self.inflight < max(int(self.max_inflight), 1)

    def snapshot(self) -> dict:
        return {"queued": self.queued, "inflight": self.inflight,
                **self.counters}


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff for transient failures."""

    max_retries: int = 2
    backoff_base_s: float = 0.02
    backoff_factor: float = 2.0

    def backoff_s(self, attempt: int, deadline: float | None = None) -> float:
        """Sleep before retry ``attempt`` (0-based).

        ``deadline`` (absolute ``time.monotonic()``) caps the sleep at the
        request's remaining budget: an exponential backoff must never be
        the thing that pushes a request past its deadline — the caller
        re-checks the deadline after the (possibly zero-length) sleep and
        fails with ``DeadlineExceededError`` instead of retrying late.
        """
        backoff = self.backoff_base_s * (self.backoff_factor ** attempt)
        if deadline is not None:
            backoff = min(backoff, max(deadline - time.monotonic(), 0.0))
        return backoff

    def is_transient(self, exc: BaseException) -> bool:
        """Retry-worthy? Exceptions that know (``InjectedFault``, the
        supervisor's ``WorkerCrashError``) carry a ``transient`` attribute
        and say so themselves; real-world compile/OOM-style errors are
        matched by message (XLA surfaces RESOURCE_EXHAUSTED through
        generic RuntimeErrors)."""
        transient = getattr(exc, "transient", None)
        if transient is not None:
            return bool(transient)
        if isinstance(exc, MemoryError):
            return True
        msg = str(exc).upper()
        return any(tag in msg for tag in
                   ("RESOURCE_EXHAUSTED", "OUT OF MEMORY", "OOM",
                    "DEADLINE_EXCEEDED_BY_BACKEND", "UNAVAILABLE"))
