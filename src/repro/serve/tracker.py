"""Pluggable metrics trackers for the mapping service (ROADMAP item 1).

PR 5's service telemetry (``stats["result_cache"]``, coalescing counters)
lived only in the process and died with it. A :class:`Tracker` is the
minimal sink abstraction that lets the same counters stream somewhere
durable — a logger, an in-memory store (tests), a JSON-lines file (one
dict per line, trivially ingestible), or several at once.

Two verbs only, both fire-and-forget and exception-safe from the caller's
point of view (a broken sink must never take down the serving path):

* ``count(name, value=1, **tags)`` — monotonic counters (admission, shed,
  retry, deadline-miss, cache hit/miss, degradation).
* ``event(name, **fields)`` — discrete structured occurrences (a request
  shed with its queue depth, a retry with its backoff).

Sinks MAY additionally expose ``gauge(name, value, **tags)`` (last-value
instruments: queue depth, cache entries) and ``snapshot()``; the service
probes for them with ``getattr`` so plain two-verb sinks keep working
(see :class:`CounterTracker`).

The service guards every emit with :func:`safe_emit`, so sinks may raise
freely (see tests). Modeled on levanter's ``Tracker`` (ROADMAP pointer)
but scoped to what the serving path needs today.
"""
from __future__ import annotations

import atexit
import json
import logging
import threading
import time
import weakref
from typing import IO


class Tracker:
    """No-op base tracker; subclasses override ``count``/``event``."""

    def count(self, name: str, value: int = 1, **tags) -> None:
        pass

    def event(self, name: str, **fields) -> None:
        pass

    def flush(self) -> None:
        pass

    def close(self) -> None:
        self.flush()


#: Shared no-op instance (the default when no tracker is wired).
NULL_TRACKER = Tracker()


def safe_emit(fn, *args, **kwargs) -> None:
    """Invoke a tracker method, swallowing sink errors: observability must
    never fail the serving path (regression-tested with a raising sink)."""
    try:
        fn(*args, **kwargs)
    except Exception:
        logging.getLogger(__name__).debug("tracker sink error", exc_info=True)


class InMemoryTracker(Tracker):
    """Accumulates counters and events in memory (tests, benchmarks)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counters: dict[str, float] = {}
        self.events: list[dict] = []

    def count(self, name: str, value: int = 1, **tags) -> None:
        key = name if not tags else \
            name + "{" + ",".join(f"{k}={v}" for k, v in sorted(tags.items())) + "}"
        with self._lock:
            self.counters[key] = self.counters.get(key, 0) + value

    def event(self, name: str, **fields) -> None:
        with self._lock:
            self.events.append({"name": name, **fields})


class LogTracker(Tracker):
    """Streams counters/events through the stdlib logging machinery."""

    def __init__(self, logger: logging.Logger | None = None,
                 level: int = logging.INFO):
        self.logger = logger or logging.getLogger("repro.serve")
        self.level = level

    def count(self, name: str, value: int = 1, **tags) -> None:
        self.logger.log(self.level, "count %s += %s %s", name, value, tags or "")

    def event(self, name: str, **fields) -> None:
        self.logger.log(self.level, "event %s %s", name, fields)


# JsonlTrackers alive at interpreter exit get a final flush. Registration
# order matters: this module is imported by serve/mapper.py BEFORE mapper
# registers its own atexit teardown, and atexit runs LIFO — so the
# service's teardown (which may emit final shed/deadline/fault events into
# a tracker) runs FIRST, and this flush runs after it, capturing those
# last events. A crash-killed process can still lose at most the current
# partially-buffered line, because writes are line-buffered.
_LIVE_JSONL: "weakref.WeakSet[JsonlTracker]" = weakref.WeakSet()


@atexit.register
def _flush_live_trackers() -> None:
    for t in list(_LIVE_JSONL):
        try:
            t.flush()
        except Exception:
            pass


class JsonlTracker(Tracker):
    """Appends one JSON object per emit to a file: a process-independent
    record of the service's admission/shed/retry/cache history.

    Crash-safe by construction: the file is opened LINE-BUFFERED, every
    emit is a single ``write()`` of one whole line, and a process-exit
    hook (ordered after the mapping service's own teardown — see
    ``_LIVE_JSONL``) flushes whatever the final teardown emitted. An
    abrupt kill can therefore truncate at most the very last line, and a
    truncated trailing line is trivially detectable by any JSONL reader.
    """

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        # buffering=1: line-buffered text mode — each full line written in
        # one call reaches the OS at the newline, not at interpreter exit.
        self._f: IO[str] | None = open(path, "a", buffering=1)
        _LIVE_JSONL.add(self)

    def _write(self, obj: dict) -> None:
        line = json.dumps(obj, default=str)
        with self._lock:
            if self._f is None:
                raise ValueError("JsonlTracker is closed")
            self._f.write(line + "\n")

    def count(self, name: str, value: int = 1, **tags) -> None:
        self._write({"t": time.time(), "kind": "count", "name": name,
                     "value": value, **tags})

    def event(self, name: str, **fields) -> None:
        self._write({"t": time.time(), "kind": "event", "name": name, **fields})

    def flush(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.flush()

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.flush()
                self._f.close()
                self._f = None
        _LIVE_JSONL.discard(self)


def _render_key(name: str, tags: tuple) -> str:
    if not tags:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in tags) + "}"


def _prom_name(name: str) -> str:
    """Prometheus metric names allow ``[a-zA-Z_:][a-zA-Z0-9_:]*``."""
    out = "".join(c if c.isalnum() or c in "_:" else "_" for c in name)
    return "_" + out if out[:1].isdigit() else (out or "_")


class CounterTracker(Tracker):
    """Prometheus-style aggregation sink (PR 10 satellite).

    Unlike :class:`InMemoryTracker` (a test spy keeping raw event dicts),
    this keeps only the AGGREGATED state an operator scrapes: monotonic
    counters and last-value gauges, keyed by ``(name, sorted tags)``.
    ``event`` emits are folded in rather than stored: each becomes a
    ``events_total{name=...}`` counter bump plus one gauge per numeric
    field (``event.<name>.<field>``) — so an unbounded event stream costs
    bounded memory.

    ``snapshot()`` returns plain dicts (what ``MappingService.stats()``
    embeds under ``"tracker"``); ``to_textfile()`` renders the Prometheus
    text exposition format and ``write_textfile(path)`` publishes it
    atomically for the node-exporter textfile collector.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[tuple[str, tuple], float] = {}
        self._gauges: dict[tuple[str, tuple], float] = {}

    @staticmethod
    def _key(name: str, tags: dict) -> tuple[str, tuple]:
        return name, tuple(sorted((k, str(v)) for k, v in tags.items()))

    def count(self, name: str, value: int = 1, **tags) -> None:
        key = self._key(name, tags)
        with self._lock:
            self._counters[key] = self._counters.get(key, 0.0) + value

    def gauge(self, name: str, value: float, **tags) -> None:
        with self._lock:
            self._gauges[self._key(name, tags)] = float(value)

    def event(self, name: str, **fields) -> None:
        numeric = {k: v for k, v in fields.items()
                   if isinstance(v, (int, float)) and not isinstance(v, bool)}
        key = self._key("events_total", {"name": name})
        with self._lock:
            self._counters[key] = self._counters.get(key, 0.0) + 1
            for k, v in numeric.items():
                self._gauges[(f"event.{name}.{k}", ())] = float(v)

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "counters": {_render_key(n, t): v
                             for (n, t), v in sorted(self._counters.items())},
                "gauges": {_render_key(n, t): v
                           for (n, t), v in sorted(self._gauges.items())},
            }

    def to_textfile(self) -> str:
        """Prometheus text exposition of the current state."""
        with self._lock:
            counters = sorted(self._counters.items())
            gauges = sorted(self._gauges.items())
        lines = []
        for kind, items in (("counter", counters), ("gauge", gauges)):
            seen = set()
            for (name, tags), val in items:
                pname = _prom_name(name)
                if pname not in seen:
                    seen.add(pname)
                    lines.append(f"# TYPE {pname} {kind}")
                label = ""
                if tags:
                    label = "{" + ",".join(
                        f'{_prom_name(k)}="{v}"' for k, v in tags) + "}"
                lines.append(f"{pname}{label} {val}")
        return "\n".join(lines) + ("\n" if lines else "")

    def write_textfile(self, path: str) -> None:
        """Atomic publish (tmp + rename): a scraper never reads a torn
        file."""
        import os
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            f.write(self.to_textfile())
        os.replace(tmp, path)


class CompositeTracker(Tracker):
    """Fans every emit out to several sinks (e.g. log + jsonl)."""

    def __init__(self, *trackers: Tracker):
        self.trackers = tuple(trackers)

    def count(self, name: str, value: int = 1, **tags) -> None:
        for t in self.trackers:
            safe_emit(t.count, name, value, **tags)

    def event(self, name: str, **fields) -> None:
        for t in self.trackers:
            safe_emit(t.event, name, **fields)

    def flush(self) -> None:
        for t in self.trackers:
            safe_emit(t.flush)

    def close(self) -> None:
        for t in self.trackers:
            safe_emit(t.close)
