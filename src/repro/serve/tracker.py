"""Pluggable metrics trackers for the mapping service (ROADMAP item 1).

PR 5's service telemetry (``stats["result_cache"]``, coalescing counters)
lived only in the process and died with it. A :class:`Tracker` is the
minimal sink abstraction that lets the same counters stream somewhere
durable — a logger, an in-memory store (tests), a JSON-lines file (one
dict per line, trivially ingestible), or several at once.

Two verbs only, both fire-and-forget and exception-safe from the caller's
point of view (a broken sink must never take down the serving path):

* ``count(name, value=1, **tags)`` — monotonic counters (admission, shed,
  retry, deadline-miss, cache hit/miss, degradation).
* ``event(name, **fields)`` — discrete structured occurrences (a request
  shed with its queue depth, a retry with its backoff).

The service guards every emit with :func:`safe_emit`, so sinks may raise
freely (see tests). Modeled on levanter's ``Tracker`` (ROADMAP pointer)
but scoped to what the serving path needs today.
"""
from __future__ import annotations

import atexit
import json
import logging
import threading
import time
import weakref
from typing import IO


class Tracker:
    """No-op base tracker; subclasses override ``count``/``event``."""

    def count(self, name: str, value: int = 1, **tags) -> None:
        pass

    def event(self, name: str, **fields) -> None:
        pass

    def flush(self) -> None:
        pass

    def close(self) -> None:
        self.flush()


#: Shared no-op instance (the default when no tracker is wired).
NULL_TRACKER = Tracker()


def safe_emit(fn, *args, **kwargs) -> None:
    """Invoke a tracker method, swallowing sink errors: observability must
    never fail the serving path (regression-tested with a raising sink)."""
    try:
        fn(*args, **kwargs)
    except Exception:
        logging.getLogger(__name__).debug("tracker sink error", exc_info=True)


class InMemoryTracker(Tracker):
    """Accumulates counters and events in memory (tests, benchmarks)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counters: dict[str, float] = {}
        self.events: list[dict] = []

    def count(self, name: str, value: int = 1, **tags) -> None:
        key = name if not tags else \
            name + "{" + ",".join(f"{k}={v}" for k, v in sorted(tags.items())) + "}"
        with self._lock:
            self.counters[key] = self.counters.get(key, 0) + value

    def event(self, name: str, **fields) -> None:
        with self._lock:
            self.events.append({"name": name, **fields})


class LogTracker(Tracker):
    """Streams counters/events through the stdlib logging machinery."""

    def __init__(self, logger: logging.Logger | None = None,
                 level: int = logging.INFO):
        self.logger = logger or logging.getLogger("repro.serve")
        self.level = level

    def count(self, name: str, value: int = 1, **tags) -> None:
        self.logger.log(self.level, "count %s += %s %s", name, value, tags or "")

    def event(self, name: str, **fields) -> None:
        self.logger.log(self.level, "event %s %s", name, fields)


# JsonlTrackers alive at interpreter exit get a final flush. Registration
# order matters: this module is imported by serve/mapper.py BEFORE mapper
# registers its own atexit teardown, and atexit runs LIFO — so the
# service's teardown (which may emit final shed/deadline/fault events into
# a tracker) runs FIRST, and this flush runs after it, capturing those
# last events. A crash-killed process can still lose at most the current
# partially-buffered line, because writes are line-buffered.
_LIVE_JSONL: "weakref.WeakSet[JsonlTracker]" = weakref.WeakSet()


@atexit.register
def _flush_live_trackers() -> None:
    for t in list(_LIVE_JSONL):
        try:
            t.flush()
        except Exception:
            pass


class JsonlTracker(Tracker):
    """Appends one JSON object per emit to a file: a process-independent
    record of the service's admission/shed/retry/cache history.

    Crash-safe by construction: the file is opened LINE-BUFFERED, every
    emit is a single ``write()`` of one whole line, and a process-exit
    hook (ordered after the mapping service's own teardown — see
    ``_LIVE_JSONL``) flushes whatever the final teardown emitted. An
    abrupt kill can therefore truncate at most the very last line, and a
    truncated trailing line is trivially detectable by any JSONL reader.
    """

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        # buffering=1: line-buffered text mode — each full line written in
        # one call reaches the OS at the newline, not at interpreter exit.
        self._f: IO[str] | None = open(path, "a", buffering=1)
        _LIVE_JSONL.add(self)

    def _write(self, obj: dict) -> None:
        line = json.dumps(obj, default=str)
        with self._lock:
            if self._f is None:
                raise ValueError("JsonlTracker is closed")
            self._f.write(line + "\n")

    def count(self, name: str, value: int = 1, **tags) -> None:
        self._write({"t": time.time(), "kind": "count", "name": name,
                     "value": value, **tags})

    def event(self, name: str, **fields) -> None:
        self._write({"t": time.time(), "kind": "event", "name": name, **fields})

    def flush(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.flush()

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.flush()
                self._f.close()
                self._f = None
        _LIVE_JSONL.discard(self)


class CompositeTracker(Tracker):
    """Fans every emit out to several sinks (e.g. log + jsonl)."""

    def __init__(self, *trackers: Tracker):
        self.trackers = tuple(trackers)

    def count(self, name: str, value: int = 1, **tags) -> None:
        for t in self.trackers:
            safe_emit(t.count, name, value, **tags)

    def event(self, name: str, **fields) -> None:
        for t in self.trackers:
            safe_emit(t.event, name, **fields)

    def flush(self) -> None:
        for t in self.trackers:
            safe_emit(t.flush)

    def close(self) -> None:
        for t in self.trackers:
            safe_emit(t.close)
