"""Parameter/batch/cache PartitionSpec rules (FSDP(data) x TP(model) baseline).

DESIGN.md §5: weights are 2D-sharded P('data','model') (ZeRO-3 gather per
layer inside the layer scan), activations batch-sharded over
('pod','data'), attention heads / d_ff / vocab sharded over 'model'
(Megatron TP). xLSTM (125M) replicates weights — model-parallelism gives
nothing at that size; see DESIGN.md §6.
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.sharding import ShardCtx

# (regex over path, base spec for the UNSTACKED leaf, trailing dims it names)
_RULES: list[tuple[str, tuple]] = [
    (r"embed/tok$", ("model", "data")),
    (r"embed/out$", ("data", "model")),
    (r"pos_(enc|dec)$", (None, None)),
    (r"patch_proj$", (None, None)),
    (r"(attn|xattn)/w[qkv]$", ("data", "model")),
    (r"(attn|xattn)/wo$", ("model", "data")),
    (r"(attn|xattn)/b[qkv]$", ("model",)),
    (r"mlp/w_(gate|up)$", ("data", "model")),
    (r"mlp/w_down$", ("model", "data")),
    (r"mlp/b_up$", ("model",)),
    (r"mlp/b_down$", (None,)),
    (r"moe/router$", (None, None)),
    (r"moe/w_(gate|up)$", ("model", None, "data", None)),
    (r"moe/w_down$", ("model", None, None, "data")),
    (r"mamba/in_proj$", ("data", "model")),
    (r"mamba/out_proj$", ("model", "data")),
    (r"mamba/conv_w$", (None, "model")),
    (r"mamba/w_[BC]$", ("model", None)),
    (r"mamba/w_dt$", ("model", None)),
    (r"mamba/(b_dt|A_log|D_skip)$", (None,)),
    # xLSTM (small model): replicated weights
    (r"(mlstm|slstm)/", ()),
    (r"norm", ()),  # norm vectors replicated
]


# TP2D ("resident weights", serving): every weight matrix is sharded over
# BOTH axes jointly on its TP dimension — no per-layer ZeRO all-gather at
# all; the only collective left is the small per-layer activation
# all-reduce. This is the §Perf H2 serving layout.
_BOTH = ("data", "model")
_RULES_TP2D: list[tuple[str, tuple]] = [
    (r"embed/tok$", (_BOTH, None)),
    (r"embed/out$", (None, _BOTH)),
    (r"pos_(enc|dec)$", (None, None)),
    (r"patch_proj$", (None, None)),
    (r"(attn|xattn)/w[qkv]$", (None, _BOTH)),
    (r"(attn|xattn)/wo$", (_BOTH, None)),
    (r"(attn|xattn)/b[qkv]$", (_BOTH,)),
    (r"mlp/w_(gate|up)$", (None, _BOTH)),
    (r"mlp/w_down$", (_BOTH, None)),
    (r"mlp/b_up$", (_BOTH,)),
    (r"mlp/b_down$", (None,)),
    (r"moe/router$", (None, None)),
    (r"moe/w_(gate|up)$", ("model", None, "data", None)),
    (r"moe/w_down$", ("model", None, None, "data")),
    (r"mamba/in_proj$", (None, _BOTH)),
    (r"mamba/out_proj$", (_BOTH, None)),
    (r"mamba/conv_w$", (None, _BOTH)),
    (r"mamba/w_[BC]$", (_BOTH, None)),
    (r"mamba/w_dt$", (_BOTH, None)),
    (r"mamba/(b_dt|A_log|D_skip)$", (None,)),
    (r"(mlstm|slstm)/", ()),
    (r"norm", ()),
]


# SEQPAR (sequence parallelism, dense archs): activations shard over
# (batch x sequence); weights ZeRO-shard over `data` only and replicate
# over `model` — every matmul is local, attention logits are Sq-sharded,
# softmax is shard-local. §Perf H7.
_RULES_SEQPAR: list[tuple[str, tuple]] = [
    (r"embed/tok$", (None, "data")),
    (r"embed/out$", ("data", None)),
    (r"pos_(enc|dec)$", (None, None)),
    (r"patch_proj$", (None, None)),
    (r"(attn|xattn)/w[qkvo]$", ("data", None)),
    (r"(attn|xattn)/b[qkv]$", (None,)),
    (r"mlp/w_(gate|up|down)$", ("data", None)),
    (r"mlp/b_(up|down)$", (None,)),
    (r"moe/router$", (None, None)),
    (r"moe/w_(gate|up)$", ("model", None, "data", None)),
    (r"moe/w_down$", ("model", None, None, "data")),
    (r"mamba/(in_proj|out_proj)$", ("data", None)),
    (r"mamba/conv_w$", (None, None)),
    (r"mamba/w_[BC]$", ("data", None)),
    (r"mamba/w_dt$", ("data", None)),
    (r"mamba/(b_dt|A_log|D_skip)$", (None,)),
    (r"(mlstm|slstm)/", ()),
    (r"norm", ()),
]

_MODE_RULES = {"fsdp": _RULES, "tp2d": _RULES_TP2D, "seqpar": _RULES_SEQPAR}


def spec_for(path: str, ndim: int, mode: str = "fsdp") -> P:
    rules = _MODE_RULES[mode]
    for pat, base in rules:
        if re.search(pat, path):
            if len(base) > ndim:
                base = base[len(base) - ndim:]
            pad = (None,) * (ndim - len(base))
            return P(*(pad + tuple(base)))
    return P(*((None,) * ndim))


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_specs(tree, mode: str = "fsdp"):
    """Pytree of PartitionSpec matching ``tree``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for(_path_str(path), leaf.ndim, mode), tree)


def param_shardings(tree, mesh: Mesh, mode: str = "fsdp"):
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                        param_specs(tree, mode))


def batch_specs(cfg: ModelConfig, batch_tree, ctx: ShardCtx):
    from repro.models.sharding import batch_spec
    bs = batch_spec(ctx)

    def one(path, leaf):
        return P(*((bs,) + (None,) * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(one, batch_tree)


def cache_specs(cfg: ModelConfig, cache_tree, ctx: ShardCtx):
    """KV caches: batch over data axes, kv heads over model; SSM states:
    batch over data, heads over model (hybrid) or replicated (xlstm)."""
    from repro.models.sharding import batch_spec
    bs = batch_spec(ctx)

    msize = ctx.model_size

    def one(path, leaf):
        p = _path_str(path)
        nd = leaf.ndim
        if re.search(r"(^|/)(k|v)$", p) or "mem_kv" in p:
            # [L?, B, S, Hkv, Dh]: shard kv heads over `model` when they
            # divide it; otherwise shard the sequence (flash-decode style —
            # softmax over the sharded axis becomes a small all-reduce).
            H, S = leaf.shape[-2], leaf.shape[-3]
            if H % msize == 0:
                base = (bs, None, "model", None)
            elif S % msize == 0:
                base = (bs, "model", None, None)
            else:
                base = (bs, None, None, None)
            pad = (None,) * (nd - len(base))
            return P(*(pad + base))
        if re.search(r"/h$", p) and nd >= 4:      # mamba h [.., B, H, N, P]
            base = (bs, "model", None, None)
            pad = (None,) * (nd - len(base))
            return P(*(pad + base))
        if re.search(r"/conv$", p):               # [.., B, K-1, d_in]
            base = (bs, None, "model")
            pad = (None,) * (nd - len(base))
            return P(*(pad + base))
        # xlstm states and misc: batch over data only (find the batch dim: 0)
        return P(*((bs,) + (None,) * (nd - 1)))

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop axis names on dims they do not evenly divide (e.g. whisper's
    odd vocab 51865 cannot be vocab-parallel over 16 devices; it falls back
    to replicated for that dim)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(entry if shape[i] % size == 0 else None)
    return P(*out)


def to_sds(tree, spec_tree, mesh: Mesh):
    """abstract tree + specs -> ShapeDtypeStructs with shardings attached."""
    return jax.tree.map(
        lambda leaf, spec: jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype,
            sharding=NamedSharding(mesh, sanitize_spec(spec, leaf.shape, mesh))),
        tree, spec_tree)
