"""Optimized-HLO text analyzer for the roofline terms.

``compiled.cost_analysis()`` on this JAX/XLA reports per-device FLOPs with
`while` bodies counted ONCE (verified empirically — see DESIGN.md §7), so we
re-derive everything from ``compiled.as_text()``:

* computations are parsed into blocks with per-op output shapes;
* `while` ops get trip counts from caller-supplied hints (the dry-run knows
  every scan length statically); multipliers propagate through the call
  graph (nested scans multiply);
* FLOPs: recomputed from `dot`/`convolution` shapes (2 * numel(out) * K) —
  elementwise FLOPs are <1% for these models and are reported separately
  from cost_analysis for cross-checking;
* collective bytes: operand bytes of all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute, trip-scaled;
* HBM bytes: fusion-aware — instruction-level ops read operands + write
  outputs; fusion-body computations are excluded (their fusion op accounts
  for them).

Everything is PER DEVICE (the HLO is the post-SPMD per-device program).
"""
from __future__ import annotations

import dataclasses
import re
import warnings
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s+([\w\-]+)\(")
_CALLED_RE = re.compile(r"(?:calls=|to_apply=|condition=|body=)%?([\w.\-]+)")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\s*\{")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_numel(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    kind: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    is_entry: bool


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and ("->" in line) and line.strip().endswith("{"):
            cur = Computation(hdr.group(1), [], line.lstrip().startswith("ENTRY"))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            cur.ops.append(Op(m.group(1), m.group(2), m.group(3), line))
    return comps


def _operands(op: Op) -> list[str]:
    """Operand names: the parenthesized list right after the op kind.

    Depending on the XLA version the operands appear bare (``%name``) or
    with their type inlined (``f32[128,256]{1,0} %name``) — the name is
    always the last whitespace-separated token.
    """
    m = re.search(re.escape(op.kind) + r"\(([^)]*)\)", op.line)
    if not m:
        return []
    # split on commas at bracket depth 0 only — inlined operand types carry
    # commas of their own (f32[128,256]{1,0})
    pieces, cur, depth = [], [], 0
    for ch in m.group(1):
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            pieces.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        pieces.append("".join(cur))
    out = []
    for o in pieces:
        toks = o.strip().split()
        if toks:
            out.append(toks[-1].lstrip("%"))
    return out


def _dot_flops(op: Op, shapes: dict[str, str]) -> int:
    """2 * numel(out) * K, K = product of lhs contracting dim sizes."""
    out_n = _shape_numel(op.type_str)
    operands = _operands(op)
    lhs_type = shapes.get(operands[0], "") if operands else ""
    dims_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    if not dims_m or not lhs_type:
        return 2 * out_n  # degenerate
    lhs_dims_m = _SHAPE_RE.search(lhs_type)
    if not lhs_dims_m:
        return 2 * out_n
    lhs_shape = [int(d) for d in lhs_dims_m.group(2).split(",") if d]
    K = 1
    for ci in dims_m.group(1).split(","):
        if ci:
            K *= lhs_shape[int(ci)]
    return 2 * out_n * K


_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _fusion_bytes(op: "Op", comps: dict) -> float:
    """Traffic of a fusion op. Loop-carry updates are fused
    dynamic-update-slices whose OUTPUT is the whole carry buffer but whose
    real (TPU, in-place) traffic is just the updated slice — detect
    DUS-rooted fusions (incl. tuple roots) and charge the slice only."""
    bodies = _CALLED_RE.findall(op.line)
    body = comps.get(bodies[0]) if bodies else None
    if body is None or not body.ops:
        return 2 * _shape_bytes(op.type_str)
    shapes = {o.name: o.type_str for o in body.ops}
    kinds = {o.name: o.kind for o in body.ops}
    root = body.ops[-1]

    def elem_bytes(name: str, fallback_type: str) -> float:
        if kinds.get(name) == "dynamic-update-slice":
            dus = next(o for o in body.ops if o.name == name)
            ops_ = _operands(dus)
            upd = shapes.get(ops_[1], "") if len(ops_) > 1 else ""
            return 2 * _shape_bytes(upd)
        return 2 * _shape_bytes(shapes.get(name, fallback_type))

    if root.kind == "dynamic-update-slice":
        return elem_bytes(root.name, root.type_str)
    if root.kind == "tuple":
        return sum(elem_bytes(o, "") for o in _operands(root))
    return 2 * _shape_bytes(op.type_str)

_MEM_SKIP = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id", "replica-id",
}


def fusion_body_set(comps: dict[str, Computation]) -> set[str]:
    """Computations called by a ``fusion`` op (accounted via the op)."""
    fusion_bodies: set[str] = set()
    for c in comps.values():
        for op in c.ops:
            if op.kind == "fusion":
                for called in _CALLED_RE.findall(op.line):
                    fusion_bodies.add(called)
    return fusion_bodies


def call_multipliers(comps: dict[str, Computation], entry_name: str,
                     fusion_bodies: set[str],
                     trip_hints: list[int] | None = None,
                     ) -> tuple[dict[str, float], list[int], int]:
    """Execution-count multipliers per computation, via DFS over the call
    graph. `while` ops consume ``trip_hints`` in DFS (nesting) order; when
    the hints run out, the LAST hint is reused (1 with no hints at all).

    Returns ``(mult, trips_used, hints_needed)`` where ``hints_needed`` is
    the number of `while` visits — callers compare it against
    ``len(trip_hints)`` to detect the shortfall (``Analysis.hints_exhausted``).
    """
    hints = list(trip_hints or [])
    hint_i = 0
    mult: dict[str, float] = defaultdict(float)
    trips_used: list[int] = []

    def visit(name: str, m: float):
        nonlocal hint_i
        if name not in comps:
            return
        mult[name] += m
        for op in comps[name].ops:
            if op.kind == "while":
                body_cond = _CALLED_RE.findall(op.line)
                if hints:
                    trip = hints[min(hint_i, len(hints) - 1)]
                else:
                    trip = 1
                hint_i += 1
                trips_used.append(trip)
                for callee in body_cond:
                    visit(callee, m * trip)
            elif op.kind in ("fusion",):
                continue  # body accounted via the fusion op itself
            elif op.kind in ("call", "conditional", "custom-call", "map",
                             "reduce", "sort", "scatter", "select-and-scatter",
                             "reduce-window", "all-reduce", "reduce-scatter"):
                for callee in _CALLED_RE.findall(op.line):
                    if callee in comps and callee not in fusion_bodies:
                        visit(callee, m)

    visit(entry_name, 1.0)
    return dict(mult), trips_used, hint_i


@dataclasses.dataclass
class Analysis:
    flops: float                     # per-device, trip-scaled (dots+convs)
    collective_bytes: dict[str, float]  # per kind, per-device, trip-scaled
    hbm_bytes: float                 # fusion-aware per-device traffic
    num_collectives: dict[str, int]
    while_trips: list[int]
    # trip-hint accounting: the DFS needed more hints than it was given
    # (the last hint was reused for the excess `while` ops — a guess).
    hints_exhausted: bool = False
    while_hints_needed: int = 0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze_hlo(hlo: str, trip_hints: list[int] | None = None) -> Analysis:
    comps = parse_computations(hlo)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    fusion_bodies = fusion_body_set(comps)
    hints = list(trip_hints or [])
    mult, trips_used, hints_needed = call_multipliers(
        comps, entry.name, fusion_bodies, hints)
    hints_exhausted = hints_needed > len(hints) and hints_needed > 0
    if hints and hints_exhausted:
        # warn once per analyze call (not per while op): silent reuse of the
        # last hint is a guess the caller should know about.
        warnings.warn(
            f"analyze_hlo: {hints_needed} `while` ops but only {len(hints)} "
            f"trip hint(s); reusing the last hint for the remainder "
            f"(trip-scaled terms are a guess past hint "
            f"#{len(hints)})", stacklevel=2)

    shapes_by_comp: dict[str, dict[str, str]] = {
        cname: {op.name: op.type_str for op in c.ops} for cname, c in comps.items()
    }

    flops = 0.0
    coll_bytes: dict[str, float] = defaultdict(float)
    coll_count: dict[str, int] = defaultdict(int)
    hbm = 0.0

    for cname, c in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0 or cname in fusion_bodies:
            # fused dots still execute: count dot flops inside fusion bodies
            # at the multiplier of their call sites.
            if cname in fusion_bodies:
                pass
            else:
                continue
        shapes = shapes_by_comp[cname]
        for op in c.ops:
            if op.kind == "dot":
                flops += m * _dot_flops(op, shapes)
            elif op.kind == "convolution":
                flops += m * 2 * _shape_numel(op.type_str) * 1  # lower bound
            if cname in fusion_bodies:
                continue  # only flops counted inside fusion bodies
            if op.kind in _COLLECTIVES:
                b = sum(_shape_bytes(shapes.get(o, "")) for o in _operands(op))
                if b == 0:
                    b = _shape_bytes(op.type_str)
                coll_bytes[op.kind] += m * b
                coll_count[op.kind] += 1
            if op.kind not in _MEM_SKIP and op.kind not in _COLLECTIVES:
                if op.kind == "fusion":
                    hbm += m * _fusion_bytes(op, comps)
                elif op.kind in ("dot", "convolution"):
                    # matmuls: stream operands from HBM + write output
                    rb = sum(_shape_bytes(shapes.get(o, "")) for o in _operands(op))
                    hbm += m * (rb + _shape_bytes(op.type_str))
                elif op.kind == "dynamic-update-slice":
                    # in-place aliased on TPU: traffic is the UPDATE slice,
                    # not the whole buffer (critical inside while carries)
                    operands = _operands(op)
                    upd = shapes.get(operands[1], "") if len(operands) > 1 else ""
                    hbm += m * 2 * _shape_bytes(upd)
                elif op.kind == "dynamic-slice":
                    hbm += m * 2 * _shape_bytes(op.type_str)
                elif op.kind == "copy":
                    pass  # while-carry copies alias on TPU
                else:
                    # perfect-fusion model: every intermediate written once
                    # and read once by its consumer(s) — this is what a TPU
                    # fusion pipeline achieves; counting operands per op on
                    # CPU-compiled (barely fused) HLO overstates traffic ~10x.
                    hbm += m * 2 * _shape_bytes(op.type_str)

    # fusion-body dot flops: attribute at the caller's multiplier
    for cname in fusion_bodies:
        if cname not in comps:
            continue
        callers = 0.0
        for on, c in comps.items():
            mm = mult.get(on, 0.0)
            if mm == 0.0:
                continue
            for op in c.ops:
                if op.kind == "fusion" and cname in _CALLED_RE.findall(op.line):
                    callers += mm
        if callers == 0.0:
            continue
        shapes = shapes_by_comp[cname]
        for op in comps[cname].ops:
            if op.kind == "dot":
                flops += callers * _dot_flops(op, shapes)

    return Analysis(
        flops=flops,
        collective_bytes=dict(coll_bytes),
        hbm_bytes=hbm,
        num_collectives=dict(coll_count),
        while_trips=trips_used,
        hints_exhausted=hints_exhausted,
        while_hints_needed=hints_needed,
    )
