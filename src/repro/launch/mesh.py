"""Production meshes — with SharedMap-driven device placement.

``make_production_mesh`` builds the assigned meshes:
  single-pod: (data=16, model=16) = 256 chips
  multi-pod : (pod=2, data=16, model=16) = 512 chips

``device_order="sharedmap"`` is the paper-as-placement-engine integration
(DESIGN.md §3): the logical communication graph of a sharded training step
(heavy TP collectives over `model`, DP ring over `data`, DCN over `pod`) is
mapped onto the physical chip hierarchy by hierarchical multisection, and
the mesh's device array is laid out accordingly. On the homogeneous
hierarchy this reproduces the default row-major order up to group symmetry
(asserted in tests) and strictly beats scrambled orders (benchmarks).
"""
from __future__ import annotations

import numpy as np

import jax

from repro.core.api import SharedMapConfig, shared_map
from repro.core.hierarchy import Hierarchy
from repro.core.taskgraph import TaskGraph


def _axis_types_kwargs(n_axes: int) -> dict:
    """``axis_types`` appeared in jax 0.5; omit it on older releases (the
    pre-0.5 default is the same Auto behaviour)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False, device_order: str = "default"):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if device_order == "default":
        return jax.make_mesh(shape, axes, **_axis_types_kwargs(len(axes)))
    if device_order == "sharedmap":
        perm = sharedmap_device_order(multi_pod=multi_pod)
        devices = np.asarray(jax.devices())[perm].reshape(shape)
        return jax.sharding.Mesh(devices, axes, **_axis_types_kwargs(len(axes)))
    raise ValueError(device_order)


def logical_comm_graph(multi_pod: bool = False,
                       w_model: float = 100.0, w_data: float = 10.0,
                       w_pod: float = 1.0) -> TaskGraph:
    """Communication graph of one train step between LOGICAL mesh positions,
    as a workload-layer :class:`TaskGraph` (PR 10 ingestion refactor —
    ``.to_graph()`` lowers it to the CSR the mapping kernels consume).

    Edge weights ~ relative bytes: TP collectives (all-gather/all-reduce
    over `model`) dominate, DP gradient ring over `data` is second, pod-axis
    DCN gradient reduction is third (but rides the slowest link — the
    hierarchy's top level).
    """
    pods = 2 if multi_pod else 1
    k = pods * 16 * 16
    idx = np.arange(k).reshape(pods, 16, 16)
    us, vs, ws = [], [], []

    def add(u, v, w):
        us.append(u.ravel())
        vs.append(v.ravel())
        ws.append(np.full(u.size, w))

    # model axis: ring segments (XLA lowers all-gather/reduce-scatter to rings)
    add(idx[:, :, :-1], idx[:, :, 1:], w_model)
    add(idx[:, :, -1], idx[:, :, 0], w_model)        # ring wrap
    # data axis: gradient reduction ring
    add(idx[:, :-1, :], idx[:, 1:, :], w_data)
    add(idx[:, -1, :], idx[:, 0, :], w_data)
    # pod axis: DCN all-reduce pairs
    if pods > 1:
        add(idx[0], idx[1], w_pod)

    u = np.concatenate(us)
    v = np.concatenate(vs)
    w = np.concatenate(ws)
    return TaskGraph.from_edges(
        k, u, v, w,
        meta={"source": "logical_mesh", "multi_pod": multi_pod,
              "weights": {"model": w_model, "data": w_data, "pod": w_pod}})


def physical_hierarchy(multi_pod: bool = False) -> Hierarchy:
    """Chip topology as a process-mapping hierarchy (innermost first):
    16 chips/rack : 16 racks/pod : pods, D = intra-rack ICI 1, inter-rack
    ICI 10, DCN 100."""
    if multi_pod:
        return Hierarchy(a=(16, 16, 2), d=(1.0, 10.0, 100.0))
    return Hierarchy(a=(16, 16), d=(1.0, 10.0))


def sharedmap_device_order(multi_pod: bool = False, seed: int = 0) -> np.ndarray:
    """perm[logical_flat_position] = physical chip id.

    n == k makes this the ONE-TO-ONE process mapping problem (OPMP/QAP), so
    the right machinery is the mapping phase of the two-phase approach
    (paper §3): Müller-Merbach greedy construction + distance-restricted
    pair swaps on the dense logical communication matrix. (Hierarchical
    multisection with singleton blocks degenerates here.) The result is
    seeded from the default (hierarchy-aligned) order, so SharedMap can only
    improve on it."""
    from repro.core.mapping import greedy_mapping, map_cost_dense, swap_refine

    tg = logical_comm_graph(multi_pod=multi_pod)
    h = physical_hierarchy(multi_pod=multi_pod)
    k = h.k
    C = np.zeros((k, k))
    np.add.at(C, (tg.u, tg.v), tg.w.astype(np.float64))
    np.add.at(C, (tg.v, tg.u), tg.w.astype(np.float64))
    D = h.distance_table()

    candidates = [np.arange(k, dtype=np.int64)]           # default order
    candidates.append(greedy_mapping(C, h))                # greedy QAP
    best = min(candidates, key=lambda p: map_cost_dense(C, D, p))
    return swap_refine(C, h, best, seed=seed)
