"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k --mesh pod1
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.jsonl
    PYTHONPATH=src python -m repro.launch.dryrun --arch whisper-tiny \
        --shape train_4k --mesh pod1 --map     # + SharedMap placement loop

Writes one JSON line per cell (incremental — crashes/restarts resume by
skipping completed cells). The roofline report reads this file.
"""
# The VERY FIRST lines, before ANY other import (jax locks the device count
# on first init):
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, SHAPES, cell_applicable, get_config
from repro.launch import shardings as SH
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.sharding import ShardCtx
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step

# TPU v5e hardware model (per chip)
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s/link


def make_ctx(mesh, multi_pod: bool, global_batch: int | None = None, **knobs) -> ShardCtx:
    axes = ("pod", "data") if multi_pod else ("data",)
    if global_batch is not None:
        # tiny batches (long_500k has B=1) cannot shard over the batch axes;
        # drop axes until the product divides the batch.
        while axes:
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
            if global_batch % prod == 0:
                break
            axes = axes[1:]
    return ShardCtx(
        mesh=mesh,
        batch_axes=axes,
        model_axis="model",
        **knobs,
    )


def lower_cell(cfg, cell, mesh, ctx, serve_bf16: bool = False):
    """Returns (lowered, trip_hints, extra_info)."""
    V = ctx.model_size
    specs = M.input_specs(cfg, cell.seq_len, cell.global_batch, cell.mode)
    batch_sds = SH.to_sds(specs, SH.batch_specs(cfg, specs, ctx), mesh)
    wmode = ctx.weight_mode

    if cell.mode == "train":
        state_abs = jax.eval_shape(
            lambda: init_train_state(cfg, jax.random.PRNGKey(0), V=V))
        pspec = SH.param_specs(state_abs.params, wmode)
        from repro.train.train_step import TrainState
        from repro.train.optimizer import OptState
        state_spec = TrainState(
            params=pspec,
            opt=OptState(step=jax.sharding.PartitionSpec(), mu=pspec, nu=pspec))
        state_sds = SH.to_sds(state_abs, state_spec, mesh)
        step = make_train_step(cfg, AdamWConfig(), ctx)
        with mesh:
            lowered = jax.jit(step, donate_argnums=(0,)).lower(state_sds, batch_sds)
        hints = M.scan_trip_hints(cfg, cell.seq_len, cell.mode,
                                  slstm_chunk=ctx.slstm_chunk)
        return lowered, hints, {}

    params_abs = jax.eval_shape(lambda: M.init_fn(cfg, jax.random.PRNGKey(0), V=V))
    if serve_bf16:  # serving checkpoints are bf16 (H3)
        params_abs = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(
                l.shape, jnp.bfloat16 if l.dtype == jnp.float32 else l.dtype),
            params_abs)
    params_sds = SH.to_sds(params_abs, SH.param_specs(params_abs, wmode), mesh)

    if cell.mode == "prefill":
        def prefill(params, batch):
            return M.prefill_fn(cfg, params, batch, ctx)
        with mesh:
            lowered = jax.jit(prefill).lower(params_sds, batch_sds)
        return lowered, M.scan_trip_hints(cfg, cell.seq_len, cell.mode,
                                          slstm_chunk=ctx.slstm_chunk), {}

    # decode: one token against a KV cache of cell.seq_len
    cache_abs = jax.eval_shape(
        lambda: M.init_cache(cfg, cell.global_batch, cell.seq_len, V=V))
    cache_sds = SH.to_sds(cache_abs, SH.cache_specs(cfg, cache_abs, ctx), mesh)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

    def decode(params, tokens, cache, pos):
        return M.decode_fn(cfg, params, tokens, cache, pos, ctx)

    with mesh:
        lowered = jax.jit(decode, donate_argnums=(2,)).lower(
            params_sds, batch_sds["tokens"], cache_sds, pos_sds)
    return lowered, M.scan_trip_hints(cfg, cell.seq_len, cell.mode,
                                      slstm_chunk=ctx.slstm_chunk), {}


def run_cell(arch: str, cell, multi_pod: bool, knobs: dict | None = None,
             map_placement: bool = False) -> dict:
    cfg = get_config(arch)
    chips = 512 if multi_pod else 256
    mesh = make_production_mesh(multi_pod=multi_pod)
    knobs = dict(knobs or {})
    serve_bf16 = knobs.pop("serve_bf16", False)
    ctx = make_ctx(mesh, multi_pod, global_batch=cell.global_batch, **knobs)
    rec = {
        "arch": arch, "shape": cell.name, "mesh": "pod2" if multi_pod else "pod1",
        "chips": chips, "mode": cell.mode,
        "knobs": {**knobs, **({"serve_bf16": True} if serve_bf16 else {})},
    }
    t0 = time.time()
    lowered, hints, _ = lower_cell(cfg, cell, mesh, ctx, serve_bf16=serve_bf16)
    rec["lower_s"] = round(time.time() - t0, 1)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "per_device_total": int(ma.argument_size_in_bytes + ma.temp_size_in_bytes
                                + ma.output_size_in_bytes - ma.alias_size_in_bytes),
    }
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax<0.5 returns one dict per program
        ca = ca[0] if ca else {}
    rec["cost_analysis"] = {"flops": float(ca.get("flops", -1)),
                            "bytes_accessed": float(ca.get("bytes accessed", -1))}

    hlo = compiled.as_text()
    an = analyze_hlo(hlo, trip_hints=hints)
    rec["hlo"] = {
        "flops_per_device": an.flops,
        "collective_bytes": an.collective_bytes,
        "collective_total": an.total_collective_bytes,
        "num_collectives": an.num_collectives,
        "hbm_bytes": an.hbm_bytes,
        "while_trips": an.while_trips,
        "trip_hints": hints,
    }
    # roofline terms (seconds, per device == per step global / chips)
    rec["roofline"] = {
        "compute_s": an.flops / PEAK_FLOPS,
        "memory_s": an.hbm_bytes / HBM_BW,
        "collective_s": an.total_collective_bytes / ICI_BW,
    }
    dom = max(rec["roofline"], key=rec["roofline"].get)
    rec["roofline"]["dominant"] = dom
    # model flops (global) for the usefulness ratio
    tokens = cell.global_batch * (cell.seq_len if cell.mode != "decode" else 1)
    n_active = cfg.active_param_count()
    mf = 6 * n_active * tokens if cell.mode == "train" else 2 * n_active * tokens
    rec["model_flops_global"] = float(mf)
    rec["useful_ratio"] = float(mf / max(an.flops * chips, 1.0))

    if map_placement:
        # PR 10 closed loop: the compiled HLO's per-op communication graph,
        # mapped onto the physical chip hierarchy by SharedMap, scored
        # against the default (program-order) placement — next to the
        # roofline collective term it would discount.
        from repro.core.api import SharedMapConfig, shared_map
        from repro.core.mapping import evaluate_J
        from repro.launch.comm_graph import default_placement, extract_comm_graph
        from repro.launch.mesh import physical_hierarchy

        h = physical_hierarchy(multi_pod)
        t0 = time.time()
        tg = extract_comm_graph(hlo, trip_hints=hints, min_tasks=2 * h.k)
        extract_s = time.time() - t0
        if tg.n < h.k:
            rec["map"] = {"skipped": f"graph has {tg.n} tasks < k={h.k}"}
        else:
            g = tg.to_graph()
            t0 = time.time()
            res = shared_map(g, h, SharedMapConfig(preset="fast"))
            map_s = time.time() - t0
            j_def = evaluate_J(g, h, default_placement(tg.n, h.k))
            rec["map"] = {
                "tasks": tg.n, "task_edges": tg.m,
                "granularity": tg.meta["granularity"],
                "extract_s": round(extract_s, 2),
                "map_s": round(map_s, 2),
                "J_sharedmap": res.J, "J_default": j_def,
                "improvement": j_def / max(res.J, 1e-12),
                "roofline_collective_s": rec["roofline"]["collective_s"],
            }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=[s.name for s in SHAPES])
    ap.add_argument("--mesh", choices=["pod1", "pod2", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--map", action="store_true", dest="map_placement",
                    help="extract the HLO communication graph and SharedMap "
                         "it onto the physical hierarchy (closed loop); adds "
                         "a 'map' record with J vs the default placement")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if "error" not in r:
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass

    cells = []
    meshes = ["pod1", "pod2"] if args.mesh == "both" else [args.mesh]
    for arch in ([args.arch] if args.arch else ARCHS):
        cfg = get_config(arch)
        for cell in SHAPES:
            if args.shape and cell.name != args.shape:
                continue
            ok, why = cell_applicable(cfg, cell)
            for mname in meshes:
                if (arch, cell.name, mname) in done:
                    continue
                cells.append((arch, cell, mname, ok, why))

    with open(args.out, "a") as f:
        for arch, cell, mname, ok, why in cells:
            tag = f"{arch} x {cell.name} x {mname}"
            if not ok:
                rec = {"arch": arch, "shape": cell.name, "mesh": mname,
                       "skipped": why}
                f.write(json.dumps(rec) + "\n")
                f.flush()
                print(f"[skip] {tag}: {why}", flush=True)
                continue
            print(f"[run ] {tag} ...", flush=True)
            try:
                rec = run_cell(arch, cell, multi_pod=(mname == "pod2"),
                               map_placement=args.map_placement)
                rl = rec["roofline"]
                print(f"[ ok ] {tag}: compute={rl['compute_s']:.3f}s "
                      f"mem={rl['memory_s']:.3f}s coll={rl['collective_s']:.3f}s "
                      f"dom={rl['dominant']} compile={rec['compile_s']}s",
                      flush=True)
                mp = rec.get("map")
                if mp and "skipped" not in mp:
                    print(f"[ map] {tag}: tasks={mp['tasks']} "
                          f"J={mp['J_sharedmap']:.3g} vs default "
                          f"{mp['J_default']:.3g} "
                          f"({mp['improvement']:.2f}x better)", flush=True)
            except Exception as e:  # record failures; the sweep continues
                rec = {"arch": arch, "shape": cell.name, "mesh": mname,
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:200]}", flush=True)
            f.write(json.dumps(rec) + "\n")
            f.flush()


if __name__ == "__main__":
    main()
