"""HLO → weighted task graph: the per-op communication-graph extractor.

``launch/hlo_analysis.py`` reduces an optimized HLO module to scalar
roofline totals. This module keeps the STRUCTURE: every executed op (or
fused group) becomes a task, every producer→consumer dataflow becomes a
weighted edge, and the result is a :class:`~repro.core.taskgraph.TaskGraph`
ready for ``shared_map`` — the paper's premise ("the communication pattern
is sparse and can be determined in advance") applied to the model zoo this
repo carries.

Graph construction (``extract_comm_graph``):

* **Tasks** — one per op of every computation the entry actually reaches
  (fusion bodies collapse into their fusion op at the default ``fused``
  granularity; ``op`` granularity expands them). Pure data-plumbing ops
  (parameter/constant/tuple/get-tuple-element/bitcast/copy) are
  TRANSPARENT: they are not tasks, and dataflow through them is followed
  to the real producer, so e.g. ``A -> tuple -> GTE -> B`` yields the edge
  ``A — B``.
* **Edge weights** — bytes of the consumed operand type, scaled by the
  computation's execution-count multiplier (the `while`-trip DFS shared
  with ``analyze_hlo``). A consumer whose operand resolves through a tuple
  to several producers splits the bytes evenly. Call boundaries (`while` /
  `call` / `conditional` / fusion ops and their callee's root) contribute
  the op's output bytes at the CALLEE's multiplier, keeping the graph
  connected across computations.
* **Collectives** — their payload re-crosses the network: operand bytes ×
  multiplier, distributed over the participating shards of the op's
  ``replica_groups`` (per-shard share = payload / group size), are added
  on top of the dataflow weight of the collective's in-edges.
* **Vertex weights** — per-op FLOPs (``_dot_flops`` for dots, 2·numel for
  convolutions; a fused group sums its body's dots), trip-scaled, floored
  at 1 so load balance over FLOP-free tasks still means "tasks per PE".

``model_comm_graph`` closes the loop for the model zoo: compile one cell
of a ``configs/`` arch on a single device at a small shape (abstract
params — no real weights are materialized) and extract its task graph.
"""
from __future__ import annotations

import re
from collections import defaultdict

import numpy as np

from repro.core.taskgraph import TaskGraph
from repro.launch.hlo_analysis import (_COLLECTIVES, _CALLED_RE, _dot_flops,
                                       _operands, _shape_bytes, _shape_numel,
                                       Computation, Op, call_multipliers,
                                       fusion_body_set, parse_computations)

# dataflow-transparent kinds: never tasks; edges pass through them
_TRANSPARENT = ("get-tuple-element", "tuple", "bitcast", "copy",
                "optimization-barrier")
# source kinds: never tasks; dataflow resolution stops at them
_SOURCES = ("parameter", "constant", "after-all", "partition-id",
            "replica-id")
# call-carrying kinds whose callee subgraphs join the task graph
_CALLERS = ("while", "call", "conditional")

_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]*)\}")


def _group_size(op: Op) -> int:
    """Participating-shard count of a collective: size of the first replica
    group (groups are uniform in SPMD HLO); 1 when unannotated."""
    m = _GROUPS_RE.search(op.line)
    if not m:
        return 1
    return max(len([d for d in m.group(1).split(",") if d]), 1)


def _op_flops(op: Op, shapes: dict[str, str],
              comps: dict[str, Computation],
              fused: bool) -> float:
    """Compute load of one task. ``fused``: a fusion op absorbs its body's
    dot FLOPs (the body's other elementwise work is <1% for these models,
    same approximation as analyze_hlo)."""
    if op.kind == "dot":
        return float(_dot_flops(op, shapes))
    if op.kind == "convolution":
        return float(2 * _shape_numel(op.type_str))
    if op.kind == "fusion" and fused:
        total = 0.0
        for body_name in _CALLED_RE.findall(op.line):
            body = comps.get(body_name)
            if body is None:
                continue
            body_shapes = {o.name: o.type_str for o in body.ops}
            for bop in body.ops:
                if bop.kind == "dot":
                    total += float(_dot_flops(bop, body_shapes))
                elif bop.kind == "convolution":
                    total += float(2 * _shape_numel(bop.type_str))
        return total
    return 0.0


def extract_comm_graph(compiled_or_hlo, trip_hints: list[int] | None = None,
                       *, granularity: str = "fused",
                       min_tasks: int | None = None,
                       meta: dict | None = None) -> TaskGraph:
    """Extract the per-op communication graph of a compiled module.

    Parameters
    ----------
    compiled_or_hlo: a ``jax`` Compiled object (anything with
        ``as_text()``) or the optimized-HLO text itself.
    trip_hints: `while` trip counts in nesting order (see
        ``analyze_hlo``); scales edge/vertex weights of loop bodies.
    granularity: ``"fused"`` (default — one task per fusion op, the
        shape XLA actually executes) or ``"op"`` (fusion bodies expand
        into per-op tasks — finer, larger graphs).
    min_tasks: with ``granularity="fused"``, re-extract at ``"op"``
        granularity when the fused graph has fewer tasks than this —
        mapping onto k PEs needs n >= k.
    """
    if granularity not in ("fused", "op"):
        raise ValueError(f"granularity must be 'fused' or 'op', "
                         f"got {granularity!r}")
    hlo = compiled_or_hlo if isinstance(compiled_or_hlo, str) \
        else compiled_or_hlo.as_text()
    comps = parse_computations(hlo)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    fusion_bodies = fusion_body_set(comps)
    hints = list(trip_hints or [])
    mult, trips_used, hints_needed = call_multipliers(
        comps, entry.name, fusion_bodies, hints)

    tg = _build(comps, entry, fusion_bodies, mult, granularity)
    if (granularity == "fused" and min_tasks is not None
            and tg.n < int(min_tasks)):
        granularity = "op"
        tg = _build(comps, entry, fusion_bodies, mult, granularity)
    tg.meta.update(meta or {})
    tg.meta.update({
        "source": "hlo",
        "entry": entry.name,
        "granularity": granularity,
        "while_trips": list(trips_used),
        "hints_exhausted": hints_needed > len(hints) and hints_needed > 0,
    })
    return tg


def _build(comps: dict[str, Computation], entry: Computation,
           fusion_bodies: set[str], mult: dict[str, float],
           granularity: str) -> TaskGraph:
    fused = granularity == "fused"

    # fusion bodies run at the summed multiplier of their call sites (the
    # DFS skips them); needed for op-granularity tasks and boundary edges.
    body_mult: dict[str, float] = defaultdict(float)
    for cname, c in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for op in c.ops:
            if op.kind == "fusion":
                for callee in _CALLED_RE.findall(op.line):
                    body_mult[callee] += m

    def comp_mult(cname: str) -> float:
        if cname in fusion_bodies:
            return 0.0 if fused else body_mult.get(cname, 0.0)
        return mult.get(cname, 0.0)

    included = [c for c in comps.values() if comp_mult(c.name) > 0.0]

    # task ids in parse order (deterministic for a given HLO text)
    task_id: dict[tuple[str, str], int] = {}
    vwgt: list[float] = []
    ops_by_name: dict[str, dict[str, Op]] = {}
    shapes_by_comp: dict[str, dict[str, str]] = {}
    for c in included:
        ops_by_name[c.name] = {op.name: op for op in c.ops}
        shapes_by_comp[c.name] = {op.name: op.type_str for op in c.ops}
        m = comp_mult(c.name)
        for op in c.ops:
            if op.kind in _TRANSPARENT or op.kind in _SOURCES:
                continue
            task_id[(c.name, op.name)] = len(vwgt)
            vwgt.append(max(m * _op_flops(op, shapes_by_comp[c.name],
                                          comps, fused), 1.0))

    edges: dict[tuple[int, int], float] = defaultdict(float)

    def add_edge(a: int, b: int, w: float) -> None:
        if a == b or w <= 0.0:
            return
        edges[(a, b) if a < b else (b, a)] += w

    def resolve(cname: str, name: str, _seen: set | None = None) -> list[int]:
        """Task ids producing value ``name`` inside computation ``cname``,
        following through transparent ops (tuple fan-in included)."""
        tid = task_id.get((cname, name))
        if tid is not None:
            return [tid]
        op = ops_by_name[cname].get(name)
        if op is None or op.kind in _SOURCES:
            return []
        seen = _seen or set()
        if name in seen:
            return []
        seen.add(name)
        out: list[int] = []
        for o in _operands(op):
            out.extend(resolve(cname, o, seen))
        return out

    for c in included:
        m = comp_mult(c.name)
        shapes = shapes_by_comp[c.name]
        for op in c.ops:
            tid = task_id.get((c.name, op.name))
            if tid is None:
                continue
            # dataflow in-edges: operand bytes from each resolved producer
            coll_share = 0.0
            if op.kind in _COLLECTIVES:
                payload = sum(_shape_bytes(shapes.get(o, ""))
                              for o in _operands(op))
                if payload == 0:
                    payload = _shape_bytes(op.type_str)
                coll_share = m * payload / _group_size(op)
            for o in _operands(op):
                producers = resolve(c.name, o)
                if not producers:
                    continue
                b = _shape_bytes(shapes.get(o, ""))
                if b == 0:  # operand shape unrecorded: fall back to output
                    b = _shape_bytes(op.type_str)
                per = (m * b + coll_share) / len(producers)
                for p in producers:
                    add_edge(p, tid, per)
            # call-boundary edges: the callee's root feeds this op's output
            # back across the boundary once per callee execution.
            callees = ()
            if op.kind in _CALLERS or (op.kind == "fusion" and not fused):
                callees = _CALLED_RE.findall(op.line)
            for callee in callees:
                body = comps.get(callee)
                if body is None or callee not in ops_by_name or not body.ops:
                    continue
                cm = comp_mult(callee)
                if cm <= 0.0:
                    continue
                w = cm * _shape_bytes(op.type_str)
                roots = resolve(callee, body.ops[-1].name)
                for p in roots:
                    add_edge(p, tid, w / len(roots))

    n = len(vwgt)
    if n == 0:
        raise ValueError("extracted task graph is empty (no executable ops)")
    if edges:
        uv = np.array(list(edges.keys()), np.int64)
        w = np.array(list(edges.values()), np.float64)
        u, v = uv[:, 0], uv[:, 1]
    else:
        u = v = np.zeros(0, np.int64)
        w = np.zeros(0, np.float64)
    return TaskGraph.from_edges(n, u, v, w, vwgt=np.asarray(vwgt))


def default_placement(n: int, k: int) -> np.ndarray:
    """The no-mapper baseline: tasks in program order, chunked onto PEs in
    default (hierarchy-aligned) order — what a launcher that ignores the
    communication pattern does. The closed-loop comparisons measure
    ``shared_map`` against this."""
    return (np.arange(int(n), dtype=np.int64) * int(k)) // max(int(n), 1)


def compile_model_cell(arch: str, *, seq_len: int = 64, batch: int = 4,
                       mode: str = "train"):
    """Compile one small single-device cell of a ``configs/`` arch and
    return ``(compiled, trip_hints)``. Parameters stay ABSTRACT
    (``jax.eval_shape``) — nothing is materialized, so this is compile-time
    only (seconds at the default tiny shape) and runs on any backend.

    Only ``mode="train"`` (the loss step) is supported here; the full
    production-mesh shapes live in ``launch/dryrun.py``.
    """
    if mode != "train":
        raise ValueError("compile_model_cell supports mode='train' only; "
                         "use launch/dryrun.py for prefill/decode cells")
    import jax

    from repro.configs.registry import get_config
    from repro.models import model as M

    cfg = get_config(arch)
    specs = M.input_specs(cfg, seq_len, batch, mode)
    params_abs = jax.eval_shape(
        lambda: M.init_fn(cfg, jax.random.PRNGKey(0), V=1))
    compiled = jax.jit(
        lambda p, b: M.loss_fn(cfg, p, b)).lower(params_abs, specs).compile()
    hints = M.scan_trip_hints(cfg, seq_len, mode)
    return compiled, hints


def model_comm_graph(arch: str, *, seq_len: int = 64, batch: int = 4,
                     granularity: str = "fused",
                     min_tasks: int | None = None) -> TaskGraph:
    """The two-step quickstart in one call: compile a tiny train cell of
    ``arch`` and extract its communication task graph."""
    compiled, hints = compile_model_cell(arch, seq_len=seq_len, batch=batch)
    return extract_comm_graph(
        compiled, hints, granularity=granularity, min_tasks=min_tasks,
        meta={"arch": arch, "seq_len": seq_len, "batch": batch,
              "mode": "train", "trip_hints": hints})
