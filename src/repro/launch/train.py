"""Training driver: real training at container scale, production mesh dry-runs
at cluster scale.

Examples:
    # ~20M-param llama-style model, 200 steps, CPU
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
        --steps 200 --batch 8 --seq 256

    # fault-tolerance demo: inject failures, auto-restart from checkpoint
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
        --steps 60 --fail-at 25 --checkpoint-every 10

    # elastic restart under a different (host-count) mesh
    ... --restore-dir ckpts/run1 --mesh none
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_config, get_smoke_config
from repro.data.pipeline import DataConfig, make_batch
from repro.models.sharding import ShardCtx
from repro.train.checkpoint import Checkpointer
from repro.train.fault_tolerance import (FailureInjector, InjectedFailure,
                                         StepWatchdog, run_with_restarts)
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


def build(args):
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.layers:
        import dataclasses
        cfg = dataclasses.replace(cfg, num_layers=args.layers)
    ctx = None
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=(args.mesh == "pod2"),
                                    device_order=args.device_order)
        ctx = ShardCtx(mesh=mesh,
                       batch_axes=("pod", "data") if args.mesh == "pod2" else ("data",))
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 5))
    return cfg, ctx, opt_cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", choices=["none", "pod1", "pod2"], default="none")
    ap.add_argument("--device-order", choices=["default", "sharedmap"], default="default")
    ap.add_argument("--checkpoint-dir", default="ckpts/run")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--restore-dir", default="")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="simulate node failures at these steps")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg, ctx, opt_cfg = build(args)
    dc = DataConfig(seq_len=args.seq, global_batch=args.batch, seed=args.seed)
    ckpt = Checkpointer(args.restore_dir or args.checkpoint_dir)
    injector = FailureInjector(fail_at_steps=tuple(args.fail_at))
    watchdog = StepWatchdog()

    train_step = jax.jit(make_train_step(cfg, opt_cfg, ctx), donate_argnums=(0,))

    def run(start_step: int) -> int:
        state = init_train_state(cfg, jax.random.PRNGKey(args.seed))
        step0 = 0
        latest = ckpt.latest_step()
        if start_step == -1 or (args.restore_dir and latest is not None):
            if latest is not None:
                restored = ckpt.restore(latest, {"params": state.params, "opt": state.opt})
                state = state._replace(params=restored["params"], opt=restored["opt"])
                step0 = latest
                print(f"[restore] resumed from step {latest}", flush=True)

        losses = []
        for step in range(step0, args.steps):
            injector.check(step)
            batch = make_batch(cfg, dc, step)
            t0 = time.time()
            state, metrics = train_step(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            if watchdog.observe(step, dt):
                print(f"[straggler] step {step} took {dt:.2f}s", flush=True)
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                toks = args.batch * args.seq / dt
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:7.1f} ms/step {toks:9.0f} tok/s", flush=True)
            if step > 0 and step % args.checkpoint_every == 0:
                ckpt.save(step, {"params": state.params, "opt": state.opt},
                          meta={"arch": cfg.name})
        ckpt.save(args.steps, {"params": state.params, "opt": state.opt},
                  meta={"arch": cfg.name}, blocking=True)
        print(f"[done] final loss {losses[-1]:.4f} (start {losses[0]:.4f})", flush=True)
        return args.steps

    run_with_restarts(
        run, max_restarts=5,
        on_restart=lambda n, e: print(f"[restart #{n}] {e}", flush=True))


if __name__ == "__main__":
    main()
