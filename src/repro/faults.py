"""Shared, deterministic fault injection (trainer + mapping service).

PR 5's trainer had its own step-indexed ``FailureInjector``
(train/fault_tolerance.py); the mapping service needs the same discipline
at its own seams (dispatch, cache, finalize) so overload/containment tests
are deterministic. This module generalizes both:

* A fault **site** is a string naming an injection seam ("dispatch",
  "cache", "finalize", "train_step", ...). Call :meth:`FaultInjector.check`
  at the seam; it raises :class:`InjectedFault` when the plan says so.
  PR 8's durability layer adds two seams with non-raise semantics at the
  consumer: ``"worker_kill"`` (serve/supervisor — a fired occurrence
  SIGKILLs the worker a task was just dispatched to, driving the
  crash-detect/restart/re-dispatch machinery deterministically) and
  ``"store_write"`` (serve/store — a fired occurrence publishes a
  deliberately TRUNCATED entry, a simulated torn write that the
  checksum-verified load must detect and quarantine).
* Two matching modes per site, usable together:

  - ``fail_at={"site": (i, j, ...)}`` — fail specific *occurrences*.
    With an explicit ``index=`` argument the indices match that value
    instead (the trainer's step-indexed mode); otherwise a per-site
    call counter is matched (the service's occurrence mode). Each
    (site, index) fires at most once, so a retry of the same seam
    succeeds — the canonical *transient* fault.
  - ``rates={"site": p}`` — fail each occurrence independently with
    probability ``p``, derived from ``(seed, site, count)`` by a hash
    counter-RNG: the fire pattern is a pure function of the plan, not of
    thread interleaving or global RNG state.

* ``transient`` marks raised faults as retry-worthy; consumers
  (serve/mapper retry policy, train restart loop) decide what that means.

Thread-safe; ``fired`` records every raised (site, index) for assertions.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from typing import Mapping, Sequence


class InjectedFault(RuntimeError):
    """Raised by a FaultInjector to simulate an infrastructure failure."""

    def __init__(self, message: str, site: str = "", index: int = -1,
                 transient: bool = True):
        super().__init__(message)
        self.site = site
        self.index = index
        self.transient = transient


def _hash_uniform(seed: int, site: str, count: int) -> float:
    """Deterministic uniform [0, 1) from (seed, site, count) — a counter
    RNG, so concurrent sites cannot perturb each other's draw sequences."""
    h = hashlib.blake2b(f"{seed}|{site}|{count}".encode(), digest_size=8)
    return int.from_bytes(h.digest(), "little") / 2.0 ** 64


@dataclasses.dataclass
class FaultInjector:
    """Deterministic seeded fault plan over named injection sites.

    Parameters
    ----------
    seed: drives the ``rates`` draws (and nothing else).
    fail_at: site -> indices that must fail (occurrence count, or the
        explicit ``index=`` passed to :meth:`check`); each fires once.
    rates: site -> independent failure probability per occurrence.
    transient: whether raised faults advertise themselves as retryable.
    error_type: exception class to raise (must accept InjectedFault's
        signature); lets the trainer keep its ``InjectedFailure`` name.
    """

    seed: int = 0
    fail_at: Mapping[str, Sequence[int]] = dataclasses.field(default_factory=dict)
    rates: Mapping[str, float] = dataclasses.field(default_factory=dict)
    transient: bool = True
    error_type: type = InjectedFault

    def __post_init__(self):
        self._lock = threading.Lock()
        self._counts: dict[str, int] = {}
        self._once: set[tuple[str, int]] = set()
        self.fired: list[tuple[str, int]] = []

    def check(self, site: str, index: int | None = None) -> None:
        """Raise at ``site`` if the plan says this occurrence fails.

        ``index`` overrides the per-site occurrence counter as the value
        matched against ``fail_at`` (e.g. the trainer passes the step).
        """
        with self._lock:
            count = self._counts.get(site, 0)
            self._counts[site] = count + 1
            idx = count if index is None else int(index)
            fire = False
            if idx in tuple(self.fail_at.get(site, ())) \
                    and (site, idx) not in self._once:
                self._once.add((site, idx))
                fire = True
            rate = float(self.rates.get(site, 0.0))
            if not fire and rate > 0.0 \
                    and _hash_uniform(self.seed, site, count) < rate:
                fire = True
            if fire:
                self.fired.append((site, idx))
        if fire:
            raise self.error_type(
                f"injected fault at {site}[{idx}]", site=site, index=idx,
                transient=self.transient)

    def count(self, site: str) -> int:
        """Occurrences checked at ``site`` so far."""
        with self._lock:
            return self._counts.get(site, 0)


#: Shared no-op plan — `check` never raises; use as the default injector.
NULL_INJECTOR = FaultInjector()
