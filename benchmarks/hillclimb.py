"""§Perf hillclimbing driver: hypothesis -> change -> re-lower -> measure.

Each plan is a sequence of knob sets applied to one (arch x shape x mesh)
cell; every step re-lowers + re-compiles and records the three roofline
terms. Results append to results/perf.jsonl and are summarized in
EXPERIMENTS.md §Perf.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell qwen2-72b:decode_32k:pod1
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json

from repro.configs.registry import SHAPES
from repro.launch.dryrun import run_cell

# (name, hypothesis, knobs) — knobs are cumulative per plan step on purpose:
# each step keeps the previous wins (the paper's methodology, §Perf).
PLANS = {
    # most representative of the paper's technique: MoE EP + multi-pod mesh
    # placed by SharedMap; dominant term at baseline: memory.
    "mixtral-8x22b:train_4k:pod2": [
        ("baseline", "paper-faithful FSDPxTP + shard_map EP MoE", {}),
        ("H1-bf16-attn",
         "QK^T/RoPE in bf16 halves the big attention tensors AND the f32 "
         "backward TP all-reduces -> memory & collective terms drop ~2x on "
         "attention-heavy portions", {"bf16_attn": True}),
        ("H2-remat-dots",
         "saving dot outputs (instead of full recompute) removes the bwd "
         "recompute pass traffic; temp memory rises but stays in budget",
         {"bf16_attn": True, "remat": "dots"}),
        ("H5-bf16-weight-gather",
         "casting master weights to bf16 BEFORE the layer scan halves the "
         "per-layer ZeRO-3 all-gather payload and the weight read traffic",
         {"bf16_attn": True, "remat": "dots", "cast_params_once": True}),
    ],
    # worst roofline fraction at baseline: decode is pure weight streaming;
    # ZeRO-3 per-layer all-gather of f32 weights dwarfs the one-token compute.
    "qwen2-72b:decode_32k:pod1": [
        ("baseline", "training layout reused for serving (f32 FSDP weights)", {}),
        ("H3-bf16-serve-weights",
         "serving weights in bf16 halve both the per-layer weight gather "
         "and the HBM streaming -> memory & collective terms /2",
         {"serve_bf16": True}),
        ("H4-tp2d-resident",
         "2D-TP resident weights eliminate the per-layer data-axis "
         "all-gather entirely; the only collective left is the tiny "
         "activation all-reduce -> collective term collapses",
         {"serve_bf16": True, "weight_mode": "tp2d"}),
    ],
    # most collective-bound at baseline: model too small for 256 chips;
    # f32 grads of attention dominate the wire.
    "llama3.2-3b:train_4k:pod1": [
        ("baseline", "FSDPxTP with f32 attention internals", {}),
        ("H1-bf16-attn",
         "bf16 QK^T + bf16 RoPE turn the f32 [B,S,D] backward all-reduces "
         "into bf16 -> collective term ~/2", {"bf16_attn": True}),
        ("H2-remat-dots",
         "keeping dot outputs kills the second forward pass in bwd -> "
         "memory term drops; collectives unchanged",
         {"bf16_attn": True, "remat": "dots"}),
        ("H5-bf16-weight-gather",
         "bf16-cast weights before the scan: ZeRO gather payload and weight "
         "reads halve (this model is collective-bound: expect a real dent)",
         {"bf16_attn": True, "remat": "dots", "cast_params_once": True}),
        ("H6-seq-shard-attn",
         "24 heads don't divide the 16-way model axis, so GSPMD partial-"
         "replicates heads and ALL-REDUCES the f32 [B,3,S,S] logits (3x90GB "
         "= the cell's wire bill). Sharding the QUERY SEQUENCE instead "
         "makes softmax shard-local: the logits all-reduce disappears and "
         "logits memory drops ~8x",
         {"bf16_attn": True, "remat": "dots", "attn_seq_shard": True}),
        ("H7-full-seqpar",
         "H6 cut collectives but GSPMD partially replicated the projections "
         "(compute x1.9). Full sequence parallelism — activations sharded "
         "(batch x seq), weights ZeRO over data + replicated over model — "
         "makes EVERY matmul local; expect compute back to ~baseline with "
         "H6's collective/memory wins kept",
         {"bf16_attn": True, "remat": "dots", "attn_seq_shard": True,
          "weight_mode": "seqpar"}),
    ],
    # worst useful-compute ratio (0.13): sLSTM is brutally memory-bound —
    # the recurrent weights are re-fetched every timestep of the 4096-long
    # time scan.
    "xlstm-125m:train_4k:pod1": [
        ("baseline", "stepwise sLSTM scan (weights fetched per timestep)", {}),
        ("H8-slstm-chunk8",
         "8 timesteps per scan iteration: recurrent weights fetched once per "
         "8 steps -> sLSTM weight traffic /8; recurrence stays exact "
         "(test_slstm_time_chunk_exact)", {"slstm_chunk": 8}),
        ("H9-slstm-chunk32",
         "32 steps/iteration: weight traffic /32; diminishing returns once "
         "activation traffic dominates", {"slstm_chunk": 32}),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(PLANS), required=True)
    ap.add_argument("--out", default="results/perf.jsonl")
    args = ap.parse_args()

    arch, shape, mesh = args.cell.split(":")
    cell = next(s for s in SHAPES if s.name == shape)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)

    with open(args.out, "a") as f:
        prev = None
        for name, hypothesis, knobs in PLANS[args.cell]:
            print(f"[perf] {args.cell} :: {name} ...", flush=True)
            rec = run_cell(arch, cell, multi_pod=(mesh == "pod2"), knobs=knobs)
            rec["plan"] = args.cell
            rec["step"] = name
            rec["hypothesis"] = hypothesis
            rl = rec["roofline"]
            if prev is not None:
                rec["delta"] = {k: rl[k] / max(prev[k], 1e-12)
                                for k in ("compute_s", "memory_s", "collective_s")}
            print(f"[perf] {name}: compute={rl['compute_s']:.3f}s "
                  f"mem={rl['memory_s']:.3f}s coll={rl['collective_s']:.3f}s "
                  f"dom={rl['dominant']}"
                  + (f" delta={rec['delta']}" if prev else ""), flush=True)
            prev = rl
            f.write(json.dumps(rec) + "\n")
            f.flush()


if __name__ == "__main__":
    main()
