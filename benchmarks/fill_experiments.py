"""Inject generated tables into EXPERIMENTS.md placeholders.

    PYTHONPATH=src python -m benchmarks.fill_experiments
"""
from __future__ import annotations

import json
import os

from benchmarks.roofline_report import dryrun_table, load, roofline_table, summary


def perf_log(path="results/perf.jsonl") -> str:
    if not os.path.exists(path):
        return "_(pending)_"
    plans: dict[str, list] = {}
    for line in open(path):
        r = json.loads(line)
        plans.setdefault(r["plan"], []).append(r)
    out = []
    for plan, steps in plans.items():
        out.append(f"### {plan}\n")
        out.append("| step | hypothesis | compute s | memory s | collective s | dominant | verdict |")
        out.append("|---|---|---|---|---|---|---|")
        prev = None
        for r in steps:
            rl = r["roofline"]
            if prev is None:
                verdict = "baseline"
            else:
                dom_prev = prev["dominant"]
                ratio = rl[dom_prev] / max(prev[dom_prev], 1e-12)
                verdict = (f"CONFIRMED: {dom_prev.replace('_s','')} x{ratio:.2f}"
                           if ratio < 0.95 else
                           (f"neutral ({dom_prev.replace('_s','')} x{ratio:.2f})"
                            if ratio < 1.05 else
                            f"REFUTED: {dom_prev.replace('_s','')} x{ratio:.2f}"))
            out.append(
                f"| {r['step']} | {r['hypothesis'][:80]} | {rl['compute_s']:.3f} "
                f"| {rl['memory_s']:.3f} | {rl['collective_s']:.3f} "
                f"| {rl['dominant'].replace('_s','')} | {verdict} |")
            prev = rl
        base, last = steps[0]["roofline"], steps[-1]["roofline"]
        dom0 = base["dominant"]
        out.append(
            f"\n**Net**: dominant term ({dom0.replace('_s','')}) "
            f"{base[dom0]:.3f}s → {last[dom0]:.3f}s "
            f"({base[dom0]/max(last[dom0],1e-12):.2f}x better); "
            f"bottleneck now: {last['dominant'].replace('_s','')}.\n")
    return "\n".join(out)


def main():
    recs = load("results/dryrun.jsonl")
    text = open("EXPERIMENTS.md").read()
    text = text.replace("<!-- DRYRUN_TABLE -->",
                        summary(recs) + "\n\n" + dryrun_table(recs))
    text = text.replace("<!-- ROOFLINE_TABLE -->", roofline_table(recs, "pod1"))
    text = text.replace("<!-- PERF_LOG -->", perf_log())
    open("EXPERIMENTS.md", "w").write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
