"""Benchmark instances: the paper's families (Table 1), container-scaled.

Sizes are configurable; defaults keep the full suite minutes-scale on one
CPU core. ``--scale paper`` in run.py lifts them toward the paper's sizes.
"""
from __future__ import annotations

from repro.core import graph as G
from repro.core.hierarchy import Hierarchy
from repro.core.taskgraph import TaskGraph

# name -> (generator, default n)
SMALL = {
    "rgg_s": (lambda n, s: G.gen_rgg(n, seed=s), 4000),        # cf. rgg23/24
    "grid_s": (lambda n, s: G.gen_grid(int(n ** 0.5)), 4096),  # cf. del23/24
    "road_s": (lambda n, s: G.gen_road(n, seed=s), 4096),      # cf. eur/deu
    "kron_s": (lambda n, s: G.gen_kron(11, seed=s), 2048),     # complex nets
}

LARGE = {
    "rgg_l": (lambda n, s: G.gen_rgg(n, seed=s), 30_000),
    "grid_l": (lambda n, s: G.gen_grid(int(n ** 0.5)), 36_864),
    "road_l": (lambda n, s: G.gen_road(n, seed=s), 36_864),
}


def instances(scale: str = "small"):
    """Yields ``(name, TaskGraph)`` per family — the generators' CSR output
    enters through the workload-ingestion layer (PR 10), so benchmark
    instances carry provenance + a content fingerprint like every other
    workload; ``.to_graph()`` recovers the CSR for kernels that need it."""
    table = dict(SMALL)
    if scale in ("large", "paper"):
        table.update(LARGE)
    mult = 8 if scale == "paper" else 1
    for name, (gen, n) in table.items():
        yield name, TaskGraph.from_graph(
            gen(n * mult, 0),
            meta={"source": "generator", "family": name, "scale": scale,
                  "seed": 0})


# the paper's experimental hierarchy family: H = 4:8:{1..6}, D = 1:10:100
def paper_hierarchies(max_c: int = 3):
    for c in range(1, max_c + 1):
        if c == 1:
            yield Hierarchy(a=(4, 8), d=(1.0, 10.0))
        else:
            yield Hierarchy(a=(4, 8, c), d=(1.0, 10.0, 100.0))
