#!/usr/bin/env python
"""Diff two BENCH_*.json telemetry files and gate on cold-path regressions.

Walks both files' ``sections`` trees, pairs up every numeric leaf present in
both, and prints the relative delta. Leaves whose dotted path contains
``cold`` are the regression gate: if NEW is slower than OLD by more than
``--threshold`` (default 20%) on any cold-path leaf, the exit code is 1 —
wire this into CI after a bench run to catch compile-path regressions.

Usage:
    python benchmarks/compare.py BENCH_OLD.json BENCH_NEW.json [--threshold 0.2]

Non-cold leaves are informational only (warm timings are min-of-reps and
noisy on shared runners; cold timings are single-shot but dominated by
compile time, which is what the fused v-cycle work targets).
"""
from __future__ import annotations

import argparse
import json
import sys


def numeric_leaves(node, prefix=""):
    """Yield (dotted_path, value) for every numeric scalar in a JSON tree."""
    if isinstance(node, bool):
        return
    if isinstance(node, (int, float)):
        yield prefix, float(node)
    elif isinstance(node, dict):
        for k, v in node.items():
            yield from numeric_leaves(v, f"{prefix}.{k}" if prefix else str(k))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from numeric_leaves(v, f"{prefix}[{i}]")


def is_cold_path(path: str) -> bool:
    return "cold" in path.lower()


def compare(old: dict, new: dict, threshold: float):
    """Return (rows, regressions): rows are (path, old, new, rel_delta, cold)."""
    old_leaves = dict(numeric_leaves(old.get("sections", old)))
    new_leaves = dict(numeric_leaves(new.get("sections", new)))
    rows, regressions = [], []
    for path in sorted(old_leaves.keys() & new_leaves.keys()):
        ov, nv = old_leaves[path], new_leaves[path]
        if ov == 0.0:
            continue  # no meaningful relative delta
        delta = (nv - ov) / abs(ov)
        cold = is_cold_path(path)
        rows.append((path, ov, nv, delta, cold))
        if cold and delta > threshold:
            regressions.append((path, ov, nv, delta))
    return rows, regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("old", help="baseline BENCH_*.json")
    ap.add_argument("new", help="candidate BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="max tolerated relative slowdown on cold-path leaves "
                         "(default 0.2 = 20%%)")
    ap.add_argument("--all", action="store_true",
                    help="print every paired leaf, not just cold-path ones")
    args = ap.parse_args(argv)

    with open(args.old) as f:
        old = json.load(f)
    with open(args.new) as f:
        new = json.load(f)

    rows, regressions = compare(old, new, args.threshold)
    if not rows:
        print("no shared numeric leaves between the two files", file=sys.stderr)
        return 2

    shown = 0
    print(f"{'path':60s} {'old':>12s} {'new':>12s} {'delta':>8s}")
    for path, ov, nv, delta, cold in rows:
        if not (cold or args.all):
            continue
        mark = " <-- REGRESSION" if cold and delta > args.threshold else ""
        print(f"{path:60s} {ov:12.4g} {nv:12.4g} {delta:+7.1%}{mark}")
        shown += 1
    print(f"# {len(rows)} shared leaves, {shown} shown, "
          f"{len(regressions)} cold-path regression(s) above "
          f"{args.threshold:.0%}")
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
