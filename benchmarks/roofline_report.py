"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun.jsonl.

    PYTHONPATH=src python -m benchmarks.roofline_report [--jsonl results/dryrun.jsonl]
"""
from __future__ import annotations

import argparse
import json
from collections import defaultdict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def load(path: str):
    recs = {}
    for line in open(path):
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        key = (r.get("arch"), r.get("shape"), r.get("mesh"))
        recs[key] = r  # later lines win (re-runs)
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def roofline_table(recs, mesh="pod1"):
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "HLO GF/dev | coll GB/dev | mem/dev | 6ND/HLO | what moves the bottleneck |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    advice = {
        ("compute_s",): "already compute-bound: increase arithmetic efficiency (fusion, bf16 remat policy)",
        ("memory_s",): "cut HBM traffic: flash/chunked attention, fewer f32 intermediates, better remat policy",
        ("collective_s",): "cut collective bytes: bf16 collectives, TP-resident weights (no ZeRO gather), comm/compute overlap",
    }
    for key in sorted(recs):
        r = recs[key]
        if r.get("mesh") != mesh:
            continue
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | — | — | — | — | {r['skipped'][:60]} |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | ERROR | — | — | — | — | {r['error'][:60]} |")
            continue
        rl = r["roofline"]
        dom = rl["dominant"]
        mem_dev = r["memory"]["per_device_total"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.4f} | {rl['memory_s']:.4f} "
            f"| {rl['collective_s']:.4f} | **{dom.replace('_s','')}** "
            f"| {r['hlo']['flops_per_device']/1e9:.0f} "
            f"| {r['hlo']['collective_total']/1e9:.2f} "
            f"| {fmt_bytes(mem_dev)} | {r['useful_ratio']:.2f} "
            f"| {advice[(dom,)]} |")
    return "\n".join(lines)


def dryrun_table(recs):
    lines = [
        "| arch | shape | mesh | status | compile s | arg bytes/dev | temp bytes/dev | collectives |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(recs):
        r = recs[key]
        if "skipped" in r:
            st, extra = "SKIP", r["skipped"][:48]
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {st} | — | — | — | {extra} |")
        elif "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | — | — | — | {r['error'][:48]} |")
        else:
            m = r["memory"]
            nc = r["hlo"]["num_collectives"]
            ncs = " ".join(f"{k.split('-')[0][0]}{k.split('-')[1][0] if '-' in k else ''}:{v}" for k, v in sorted(nc.items()))
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {r.get('compile_s','-')} "
                f"| {fmt_bytes(m['argument_bytes'])} | {fmt_bytes(m['temp_bytes'])} | {ncs} |")
    return "\n".join(lines)


def summary(recs):
    n_ok = sum(1 for r in recs.values() if "roofline" in r)
    n_skip = sum(1 for r in recs.values() if "skipped" in r)
    n_err = sum(1 for r in recs.values() if "error" in r)
    doms = defaultdict(int)
    for r in recs.values():
        if "roofline" in r and r["mesh"] == "pod1":
            doms[r["roofline"]["dominant"]] += 1
    return (f"cells: {n_ok} compiled ok, {n_skip} skipped (assignment rules), "
            f"{n_err} failed. pod1 dominant terms: {dict(doms)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="results/dryrun.jsonl")
    ap.add_argument("--section", choices=["roofline", "dryrun", "summary", "all"],
                    default="all")
    args = ap.parse_args()
    recs = load(args.jsonl)
    if args.section in ("summary", "all"):
        print("## Summary\n")
        print(summary(recs) + "\n")
    if args.section in ("roofline", "all"):
        print("## Roofline (single-pod 16x16, per device per step)\n")
        print(roofline_table(recs, "pod1") + "\n")
    if args.section in ("dryrun", "all"):
        print("## Dry-run (all cells x both meshes)\n")
        print(dryrun_table(recs) + "\n")


if __name__ == "__main__":
    main()
