"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--scale small|large]

Prints ``name,us_per_call,derived`` CSV rows per the repo contract; each
section maps to a paper artifact (DESIGN.md §8):

    quality_profiles   Fig 5/6  — solution quality vs baselines
    thread_strategies  Fig 3    — NAIVE/LAYER/BUCKET/QUEUE scheduling
    presets            Fig 2    — FAST/ECO/STRONG trade-off
    scalability        Fig 4    — restart-lane scaling (vmap width)
    mapping_vs_default —        — SharedMap device order for the prod mesh
    kernels            —        — Pallas kernel oracles timing
    serve              —        — mapping service: cached-repeat latency and
                                  cross-request batched throughput (PR5)
    serve_overload     —        — admission control under an arrival-rate
                                  ramp (p50/p99 latency, shed rate) and a
                                  fault-injection sweep (PR6)
    device_pipeline    —        — device-resident multisection vs the PR5
                                  host-mirror loop: per-request wall time
                                  and host<->device transfer traffic (PR7)
    durability         —        — persistent result store: warm-restart
                                  hit latency vs cold compute vs in-memory
                                  LRU hit, and the persistence-tier write
                                  overhead on the compute path (PR8)
    coarsen_kernels    —        — device-resident coarsening + fused
                                  v-cycle at 10^5/10^6 vertices: per-stage
                                  cold wall, per-level shrink, peak RSS,
                                  fused vs unrolled-segment cold path (PR9)
    model_graphs       —        — the ingestion closed loop: compile a
                                  model-zoo arch, extract its HLO comm
                                  graph (TaskGraph), SharedMap it onto the
                                  physical hierarchy, J vs the default
                                  placement (PR10; doubles as CI smoke)
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

ROWS: list[tuple[str, float, str]] = []

# structured telemetry merged into BENCH_PR3.json at exit (perf trajectory
# tracking from PR 3 onward: strategy wall times, partition_calls,
# padded-vs-real vertex work, compile-cache hits, map costs).
BENCH: dict = {"sections": {}}


def emit(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def bench_quality_profiles(scale: str, quick: bool):
    from benchmarks.instances import instances, paper_hierarchies
    from repro.core.api import SharedMapConfig, shared_map
    from repro.core.baselines import (global_multisection, kaffpa_map_style,
                                      random_mapping)
    from repro.core.mapping import evaluate_J

    # sharedmap_r = SharedMap + the same swap pass GM gets (apples-to-apples
    # with our substrate partitioner; DESIGN.md §2.3, EXPERIMENTS deviations)
    algos = ["sharedmap", "sharedmap_r", "gm", "random"] + ([] if quick else ["kaffpamap"])
    hs = list(paper_hierarchies(1 if quick else 2))
    results = {a: [] for a in algos}
    for gname, tg in instances(scale):
        g = tg.to_graph()  # baselines + evaluate_J run on the CSR form
        for h in hs:
            for algo in algos:
                t0 = time.time()
                if algo == "sharedmap":
                    # fed the TaskGraph form: exercises the ingestion layer
                    J = shared_map(tg, h, SharedMapConfig(preset="fast")).J
                elif algo == "sharedmap_r":
                    J = shared_map(tg, h, SharedMapConfig(preset="fast",
                                                          refine_mapping=True)).J
                elif algo == "gm":
                    res = global_multisection(g, h, preset="fast")
                    J = evaluate_J(g, h, res.pe_of)
                elif algo == "kaffpamap":
                    try:
                        res = kaffpa_map_style(g, h, preset="fast")
                        J = evaluate_J(g, h, res.pe_of)
                    except ValueError:
                        continue  # non power-of-two k
                else:
                    J = evaluate_J(g, h, random_mapping(g, h))
                dt = time.time() - t0
                results[algo].append((gname, str(h), J, dt))
                emit(f"quality/{algo}/{gname}/k{h.k}", dt * 1e6, f"J={J:.0f}")
    # performance profile at tau=1 (fraction of instances with best J)
    keys = [(g0, h0) for (g0, h0, _, _) in results["sharedmap"]]
    best_count = {a: 0 for a in algos}
    for i, key in enumerate(keys):
        js = {a: results[a][i][2] for a in algos if i < len(results[a])}
        best = min(js.values())
        for a, j in js.items():
            if j <= best * 1.0001:
                best_count[a] += 1
    for a in algos:
        emit(f"profile_tau1/{a}", 0.0, f"best_on={best_count[a]}/{len(keys)}")


def bench_thread_strategies(scale: str, quick: bool):
    from benchmarks.instances import instances
    from repro.core.api import SharedMapConfig, shared_map
    from repro.core.hierarchy import Hierarchy

    import jax
    h = Hierarchy(a=(4, 8, 2), d=(1.0, 10.0, 100.0))
    strategies = ["naive", "layer", "bucket", "queue"]
    from repro.core.multisection import clear_compile_cache
    section = BENCH["sections"].setdefault("thread_strategies", {})
    for gname, g in instances(scale):
        jax.clear_caches()
        clear_compile_cache()
        times = {}
        reps = 3  # min-of-reps: wall clock on shared/throttled hosts is noisy
        for s in strategies:
            shared_map(g, h, SharedMapConfig(preset="fast", strategy=s))  # warm
            best = float("inf")
            for _ in range(reps):
                t0 = time.time()
                res = shared_map(g, h, SharedMapConfig(preset="fast", strategy=s))
                best = min(best, time.time() - t0)
            times[s] = best
            waste = res.stats["padded_vertex_work"] / max(res.stats["real_vertex_work"], 1)
            cc = res.stats["compile_cache"]
            emit(f"strategy/{s}/{gname}", times[s] * 1e6,
                 f"padwaste={waste:.2f} cache={cc['hits']}h/{cc['misses']}m")
            section[f"{s}/{gname}"] = {
                "wall_s": times[s],
                "J": res.J,
                "partition_calls": res.stats["partition_calls"],
                "padded_vertex_work": res.stats["padded_vertex_work"],
                "real_vertex_work": res.stats["real_vertex_work"],
                "compile_cache_hits": cc["hits"],
                "compile_cache_misses": cc["misses"],
                "backend": res.stats["backend"],
            }
        base = times["layer"]
        for s in strategies:
            emit(f"strategy_speedup_vs_layer/{s}/{gname}", times[s] * 1e6,
                 f"speedup={base / times[s]:.2f}")
        if quick:
            break


def bench_presets(scale: str, quick: bool):
    from benchmarks.instances import instances
    from repro.core.api import SharedMapConfig, shared_map
    from repro.core.hierarchy import Hierarchy

    h = Hierarchy(a=(4, 8), d=(1.0, 10.0))
    presets = ["fast", "eco"] + ([] if quick else ["strong"])
    for gname, g in instances(scale):
        ref = None
        for p in presets:
            t0 = time.time()
            res = shared_map(g, h, SharedMapConfig(preset=p))
            dt = time.time() - t0
            ref = ref or res.J
            emit(f"preset/{p}/{gname}", dt * 1e6, f"J={res.J:.0f} vs_fast={res.J/ref:.3f}")
        if quick:
            break


def bench_scalability(scale: str, quick: bool):
    """Lane scaling: vmapped seeded restarts are the TPU analogue of adding
    threads to one partition call (KaFFPa-style repetitions)."""
    import jax
    import jax.numpy as jnp
    from benchmarks.instances import instances
    from repro.core.partition import num_levels, partition

    gname, tg = next(instances(scale))
    g = tg.to_graph()
    lv = num_levels(int(g.n), 8)
    for lanes in ([1, 4] if quick else [1, 2, 4, 8]):
        def run(salts):
            return jax.vmap(lambda s: partition(g, 8, jnp.float32(0.03), lv, "fast", s))(salts)
        salts = jnp.arange(lanes, dtype=jnp.int32)
        run(salts)  # compile
        t0 = time.time()
        jax.block_until_ready(run(salts))
        dt = time.time() - t0
        emit(f"scalability/lanes{lanes}/{gname}", dt * 1e6,
             f"per_lane_us={dt*1e6/lanes:.0f}")


def bench_mapping_vs_default(scale: str, quick: bool):
    from repro.core.mapping import evaluate_J
    from repro.launch.mesh import (logical_comm_graph, physical_hierarchy,
                                   sharedmap_device_order)

    for multi_pod in (False, True):
        g = logical_comm_graph(multi_pod).to_graph()
        h = physical_hierarchy(multi_pod)
        k = h.k
        t0 = time.time()
        perm = sharedmap_device_order(multi_pod)
        dt = time.time() - t0
        j_sm = evaluate_J(g, h, perm)
        j_def = evaluate_J(g, h, np.arange(k))
        rng = np.random.default_rng(0)
        j_rnd = float(np.mean([evaluate_J(g, h, rng.permutation(k)) for _ in range(3)]))
        emit(f"device_order/sharedmap/pod{2 if multi_pod else 1}", dt * 1e6,
             f"J={j_sm:.0f} default={j_def:.0f} random={j_rnd:.0f}")


def bench_refine_backends(scale: str, quick: bool):
    """ELL/Pallas-backed refinement vs the seed XLA scatter path: final
    edge-cut parity and wall time of whole partition calls."""
    import jax
    from benchmarks.instances import instances
    from repro.core.graph import edge_cut
    from repro.core.partition import partition_host

    section = BENCH["sections"].setdefault("refine_backends", {})
    for gname, tg in instances(scale):
        g = tg.to_graph()
        row = {}
        for be in ("xla", "ell"):
            jax.block_until_ready(partition_host(g, 8, 0.03, "fast", salt=1, backend=be))  # warm
            dt = float("inf")
            for _ in range(3):  # min-of-reps (noisy shared host)
                t0 = time.time()
                part = jax.block_until_ready(partition_host(g, 8, 0.03, "fast", salt=1, backend=be))
                dt = min(dt, time.time() - t0)
            cut = float(edge_cut(g, part))
            row[be] = {"wall_s": dt, "edge_cut": cut}
            emit(f"refine_backend/{be}/{gname}", dt * 1e6, f"cut={cut:.0f}")
        section[gname] = row
        if quick:
            break


def bench_kernels(scale: str, quick: bool):
    import jax
    import jax.numpy as jnp
    from repro.core import graph as G
    from repro.core.hierarchy import Hierarchy
    from repro.kernels import ops, ref

    g = G.gen_rgg(20_000, seed=0)
    h = Hierarchy(a=(16, 16), d=(1.0, 10.0))
    rng = np.random.default_rng(0)
    pe = jnp.asarray(rng.integers(0, h.k, g.N), jnp.int32)
    gb = jnp.asarray((1,) + h.strides[:-1], jnp.int32)
    dv = jnp.asarray(h.d, jnp.float32)
    f = jax.jit(lambda: ref.mapcost_ref(g.rows, g.cols, g.ewgt, pe, gb, dv))
    jax.block_until_ready(f())
    t0 = time.time()
    for _ in range(10):
        jax.block_until_ready(f())
    us = (time.time() - t0) / 10 * 1e6
    emit("kernel/mapcost_ref_20k", us, f"edges_per_s={int(g.m)/(us/1e6):.2e}")

    k = 16
    part = jnp.asarray(rng.integers(0, k, g.N), jnp.int32)
    adj, adw = ref.csr_to_ell(g.rows, g.cols, g.ewgt, g.N, 16)
    f2 = jax.jit(lambda: ref.lp_gain_ref(adj, adw, part, k))
    jax.block_until_ready(f2())
    t0 = time.time()
    for _ in range(10):
        jax.block_until_ready(f2())
    us = (time.time() - t0) / 10 * 1e6
    emit("kernel/lp_gain_ref_20k", us, f"vertices_per_s={int(g.n)/(us/1e6):.2e}")
    BENCH["sections"].setdefault("kernels", {})["lp_gain_ref_20k_us"] = us

    # mapcost through the single dispatch helper (pallas on TPU, oracle here)
    f3 = jax.jit(lambda: ops.mapcost(g.rows, g.cols, g.ewgt, pe, gb, dv))
    jax.block_until_ready(f3())
    t0 = time.time()
    for _ in range(10):
        jax.block_until_ready(f3())
    us = (time.time() - t0) / 10 * 1e6
    emit("kernel/mapcost_dispatch_20k", us, f"backend={ops.kernel_backend()}")
    BENCH["sections"]["kernels"]["mapcost_dispatch_20k_us"] = us
    BENCH["sections"]["kernels"]["backend"] = ops.kernel_backend()


def bench_serve(scale: str, quick: bool):
    """Mapping service vs sequential shared_map: cached-repeat latency and
    cross-request coalesced throughput.

    Workload: a burst of distinct small communication graphs on a DEEP
    hierarchy — the service's target traffic. Small instances and many
    hierarchy levels mean many tiny per-request dispatches, which is where
    per-dispatch overhead rivals partition compute and coalescing pays;
    large single mappings stay compute-bound and gain little (that regime
    is benchmarked by thread_strategies).

    The throughput service runs with the result cache DISABLED and the
    timed reps reuse the warm seeds: burst composition (and therefore the
    compiled batch widths) is deterministic, so the measurement is
    steady-state compute, free of both compile noise and cache shortcuts.
    """
    from repro.core import graph as G
    from repro.core.api import SharedMapConfig, shared_map_direct
    from repro.core.hierarchy import Hierarchy
    from repro.serve.mapper import MappingService

    h = Hierarchy(a=(2, 2, 2, 2), d=(1.0, 5.0, 10.0, 100.0))
    R = 8 if quick else 24
    n = 64
    seeds = (1, 2) if quick else (1, 2, 3)
    gs = [G.gen_rgg(n, seed=100 + i) for i in range(R)]
    cfg = SharedMapConfig(preset="fast")
    section = BENCH["sections"].setdefault("serve", {})

    # sequential baseline (direct path), warmed by its own first sweep
    for s in seeds:
        for g in gs:
            shared_map_direct(g, h, SharedMapConfig(preset="fast", seed=s))
    seq = float("inf")
    for s in seeds:
        t0 = time.time()
        for g in gs:
            shared_map_direct(g, h, SharedMapConfig(preset="fast", seed=s))
        seq = min(seq, time.time() - t0)
    emit(f"serve/sequential_direct/{R}x_rgg{n}", seq * 1e6,
         f"per_req_ms={seq/R*1e3:.1f}")

    svc = MappingService(cache_entries=0)  # throughput: no result cache
    try:
        # COLD first-request latency: the service's vmapped B=1 programs
        # are distinct from the direct path's, so this pays their compiles
        # — the number warmup() exists to hide.
        t0 = time.time()
        first = svc.map(gs[0], h, cfg)
        cold_s = time.time() - t0
        emit(f"serve/first_request_cold/rgg{n}", cold_s * 1e6,
             f"cache_hit={first.stats['result_cache']['hit']}")

        for s in seeds:  # warm the merged batch widths
            for f in svc.submit_many([(g, h, SharedMapConfig(preset="fast",
                                                             seed=s))
                                      for g in gs]):
                f.result()
        bat = float("inf")
        for s in seeds:
            t0 = time.time()
            futs = svc.submit_many([(g, h, SharedMapConfig(preset="fast",
                                                           seed=s))
                                    for g in gs])
            for f in futs:
                f.result()
            bat = min(bat, time.time() - t0)
        tput = seq / bat
        emit(f"serve/batched_service/{R}x_rgg{n}", bat * 1e6,
             f"throughput_vs_sequential={tput:.2f}x")
        co = svc.stats()["coalesce"]
    finally:
        svc.close()

    # cached-repeat latency on a caching service (identical request twice)
    svc2 = MappingService()
    try:
        svc2.map(gs[0], h, cfg)
        t0 = time.time()
        hit_reps = 20
        for _ in range(hit_reps):
            res = svc2.map(gs[0], h, cfg)
        hit_s = (time.time() - t0) / hit_reps
        assert res.stats["result_cache"]["hit"] is True
        cached_speedup = (seq / R) / hit_s
        emit(f"serve/cached_repeat/rgg{n}", hit_s * 1e6,
             f"speedup_vs_compute={cached_speedup:.0f}x")
        rc = svc2.stats()["result_cache"]
    finally:
        svc2.close()

    section.update({
        "requests": R,
        "instance": f"rgg{n}",
        "hierarchy": "x".join(map(str, h.a)),
        "sequential_wall_s": seq,
        "batched_wall_s": bat,
        "throughput_speedup": tput,
        "cached_repeat_s": hit_s,
        "cached_speedup": cached_speedup,
        "coalesce": co,
        "result_cache": rc,
    })


def bench_serve_overload(scale: str, quick: bool):
    """Overload behavior of the admission-controlled service (PR6).

    Two experiments on deliberately small bounds (max_inflight=2,
    max_queue=4 — the point is to saturate, whatever the host):

    * **Arrival-rate ramp** — open-loop Poisson-ish arrivals at increasing
      rates; per-rate p50/p99 completion latency of ADMITTED requests and
      the shed rate. Past saturation the shed rate climbs while admitted
      latency stays bounded — that is the load-shedding contract (an
      unbounded queue would instead blow up latency for everyone).
    * **Fault-injection sweep** — a burst under a 25% transient dispatch
      failure rate: every future must resolve with a result (possibly
      degraded) or a typed ServiceOverloadError; retries/degradations are
      reported from the service's own telemetry.
    """
    from repro.core import graph as G
    from repro.core.api import SharedMapConfig
    from repro.core.hierarchy import Hierarchy
    from repro.faults import FaultInjector
    from repro.serve.admission import RetryPolicy, ServiceOverloadError
    from repro.serve.mapper import MappingService

    h = Hierarchy(a=(2, 2, 2), d=(1.0, 10.0, 100.0))
    n = 64
    R = 12 if quick else 32
    gs = [G.gen_rgg(n, seed=300 + i) for i in range(R)]
    section = BENCH["sections"].setdefault("serve_overload", {})

    # warm the programs the BOUNDED service will actually run: with
    # max_inflight=2 the coalesced widths are 1-2, so feed pairs
    # closed-loop (a big submit_many burst would only warm the wide
    # merged widths and the ramp would measure compiles, not serving)
    warm = MappingService(cache_entries=0, max_inflight=2)
    try:
        for j in range(0, R, 2):
            for f in warm.submit_many([(g, h, SharedMapConfig(preset="fast",
                                                              seed=i))
                                       for i, g in enumerate(gs[j:j + 2], j)]):
                f.result()
    finally:
        warm.close()

    for rate in ([50, 400] if quick else [25, 100, 400]):  # requests/s
        svc = MappingService(max_inflight=2, max_queue=4, cache_entries=0)
        lat: list[float] = []
        shed = 0
        try:
            futs = []
            t_start = time.time()
            for i, g in enumerate(gs):
                target = t_start + i / rate  # open-loop arrivals
                delay = target - time.time()
                if delay > 0:
                    time.sleep(delay)
                t0 = time.time()
                try:
                    f = svc.submit(g, h, SharedMapConfig(preset="fast", seed=i))
                except ServiceOverloadError:
                    shed += 1
                    continue

                def _done(fut, t0=t0):
                    if fut.exception() is None:
                        lat.append(time.time() - t0)

                f.add_done_callback(_done)
                futs.append(f)
            for f in futs:
                f.exception(timeout=600)  # wait; sheds were counted above
        finally:
            svc.close()
        lat.sort()
        p50 = lat[len(lat) // 2] if lat else float("nan")
        p99 = lat[min(int(len(lat) * 0.99), len(lat) - 1)] if lat else float("nan")
        shed_rate = shed / R
        emit(f"serve_overload/rate{rate}/p99", p99 * 1e6,
             f"p50_ms={p50*1e3:.1f} shed_rate={shed_rate:.2f}")
        section[f"rate{rate}"] = {
            "requests": R, "admitted": len(lat), "shed": shed,
            "shed_rate": shed_rate, "p50_s": p50, "p99_s": p99,
        }

    # fault-injection sweep: all futures resolve, typed errors only. The
    # queue admits the whole burst (this experiment is about containment,
    # not shedding — the ramp above measures that).
    inj = FaultInjector(seed=1, rates={"dispatch": 0.25})
    svc = MappingService(max_inflight=2, max_queue=R, fault_injector=inj,
                         retry=RetryPolicy(max_retries=1,
                                           backoff_base_s=0.001))
    ok = shed = degraded = 0
    try:
        t0 = time.time()
        futs = svc.submit_many([(g, h, SharedMapConfig(preset="fast",
                                                       seed=1000 + i))
                                for i, g in enumerate(gs)])
        for f in futs:
            exc = f.exception(timeout=600)
            if exc is None:
                ok += 1
                if f.result().stats["degradation"]["level"] > 0:
                    degraded += 1
            elif isinstance(exc, ServiceOverloadError):
                shed += 1
            else:
                raise AssertionError(f"untyped failure escaped: {exc!r}")
        wall = time.time() - t0
        flt = svc.stats()["faults"]
    finally:
        svc.close()
    assert ok + shed == R, (ok, shed, R)
    emit(f"serve_overload/fault_sweep/{R}x_rgg{n}", wall * 1e6,
         f"ok={ok} shed={shed} degraded={degraded} retries={flt['retries']}")
    section["fault_sweep"] = {
        "requests": R, "ok": ok, "shed": shed, "degraded": degraded,
        "dispatch_failures": flt["dispatch_failures"],
        "retries": flt["retries"], "contained": flt["contained"],
        "wall_s": wall,
    }


def bench_device_pipeline(scale: str, quick: bool):
    """Device-resident level loop vs the PR5 host-mirror loop (PR7).

    Workload: a burst of rgg64-class graphs on a DEEP hierarchy — many
    levels of small dispatches, where per-level host round-trips dominate.
    Three pipelines, bit-identical outputs (tested in tests/):

    * ``host_mirror``     — bucket, resident=False: the PR5 reference;
                            per-level bulk label fetch + child re-upload.
    * ``bucket_resident`` — bucket, resident=True (the new default):
                            children stay on device, [B] metadata per level.
    * ``device``          — strategy=device: fixed root-shape schedule,
                            exactly ONE array fetch per request (asserted).

    Per mode we report min-of-reps wall time per request plus the transfer
    counters (bytes and fetch counts per request) from one instrumented
    sweep — the protocol cost an accelerator-attached host would pay.
    """
    from repro.core import graph as G
    from repro.core.hierarchy import Hierarchy
    from repro.core.multisection import (hierarchical_multisection,
                                         reset_transfer_stats,
                                         transfer_stats)

    h = Hierarchy(a=(2, 2, 2, 2), d=(1.0, 5.0, 10.0, 100.0))
    R = 4 if quick else 12
    n = 64
    gs = [G.gen_rgg(n, seed=500 + i) for i in range(R)]
    reps = 2 if quick else 3
    modes = [
        ("host_mirror", dict(strategy="bucket", resident=False)),
        ("bucket_resident", dict(strategy="bucket")),
        ("device", dict(strategy="device")),
    ]
    section = BENCH["sections"].setdefault("device_pipeline", {})
    base = None
    for mode, kw in modes:
        for i, g in enumerate(gs):  # warm every program this mode needs
            hierarchical_multisection(g, h, preset="fast", seed=i, **kw)
        reset_transfer_stats()
        for i, g in enumerate(gs):  # instrumented sweep (warm)
            res = hierarchical_multisection(g, h, preset="fast", seed=i, **kw)
        xf = transfer_stats()
        best = float("inf")
        for _ in range(reps):
            t0 = time.time()
            for i, g in enumerate(gs):
                hierarchical_multisection(g, h, preset="fast", seed=i, **kw)
            best = min(best, time.time() - t0)
        per_req = best / R
        fetches = xf["d2h_array_fetches"] / R
        d2h_kb = (xf["d2h_bytes"] + xf["d2h_meta_bytes"]) / R / 1e3
        h2d_kb = xf["h2d_bytes"] / R / 1e3
        if mode == "device":
            assert xf["d2h_array_fetches"] == R, xf  # ONE fetch per request
        base = base or per_req
        emit(f"device_pipeline/{mode}/{R}x_rgg{n}", per_req * 1e6,
             f"speedup_vs_host={base/per_req:.2f} d2h_fetches_per_req="
             f"{fetches:.1f} d2h_kb_per_req={d2h_kb:.1f}")
        section[mode] = {
            "requests": R, "instance": f"rgg{n}",
            "hierarchy": "x".join(map(str, h.a)),
            "wall_s_per_request": per_req,
            "speedup_vs_host_mirror": base / per_req,
            "J": res.J if hasattr(res, "J") else None,
            "transfers_per_request": {
                "d2h_array_fetches": fetches,
                "d2h_meta_fetches": xf["d2h_meta_fetches"] / R,
                "d2h_kb": d2h_kb,
                "h2d_transfers": xf["h2d_transfers"] / R,
                "h2d_kb": h2d_kb,
            },
        }


def bench_durability(scale: str, quick: bool):
    """Persistence tier of the mapping service (PR8).

    Three latencies for the SAME request: cold compute (empty caches),
    in-memory LRU repeat, and a store hit after a "process restart" (a
    fresh service opened on the same store directory — LRU cold, disk
    warm). Plus the write overhead the durable tier adds to the compute
    path: a burst of distinct requests with and without a store attached.
    The store hit pays decode + checksum but skips partitioning entirely,
    so it should land between the LRU hit and cold compute — orders of
    magnitude below the latter.
    """
    import shutil
    import tempfile

    from repro.core import graph as G
    from repro.core.api import SharedMapConfig
    from repro.core.hierarchy import Hierarchy
    from repro.serve.mapper import MappingService

    h = Hierarchy(a=(2, 2, 2), d=(1.0, 10.0, 100.0))
    n = 64
    R = 4 if quick else 12
    gs = [G.gen_rgg(n, seed=200 + i) for i in range(R)]
    cfg = SharedMapConfig(preset="fast")
    section = BENCH["sections"].setdefault("durability", {})
    root = tempfile.mkdtemp(prefix="bench_durability_")
    try:
        path = f"{root}/store"
        svc = MappingService(store_path=path, batch_window_s=0.0)
        try:
            t0 = time.time()
            cold = svc.map(gs[0], h, cfg)
            cold_s = time.time() - t0
            assert cold.stats["result_cache"]["hit"] is False
            emit(f"durability/cold_compute/rgg{n}", cold_s * 1e6, "")

            hit_reps = 20
            t0 = time.time()
            for _ in range(hit_reps):
                res = svc.map(gs[0], h, cfg)
            lru_s = (time.time() - t0) / hit_reps
            assert res.stats["result_cache"]["hit"] is True
            emit(f"durability/lru_hit/rgg{n}", lru_s * 1e6,
                 f"speedup_vs_cold={cold_s/lru_s:.0f}x")
        finally:
            svc.close()

        # "restarted process": fresh service, same directory. First map()
        # must come from disk, not recompute — assert via store telemetry.
        svc2 = MappingService(store_path=path, batch_window_s=0.0)
        try:
            reps = 5
            warm_s = float("inf")
            for i in range(reps):
                t0 = time.time()
                res = svc2.map(gs[0], h, cfg)
                warm_s = min(warm_s, time.time() - t0)
                if i == 0:
                    assert svc2.stats()["store"]["hits"] == 1
                    first_restart_s = time.time() - t0
                # evict so every rep re-reads the disk tier, not the LRU
                svc2._cache.clear()
                svc2._by_graph.clear()
            assert res.stats["result_cache"]["hit"] is True
            emit(f"durability/store_hit_after_restart/rgg{n}", warm_s * 1e6,
                 f"speedup_vs_cold={cold_s/warm_s:.0f}x")
        finally:
            svc2.close()

        # persistence overhead on the compute path: distinct requests so
        # every one computes AND (with a store) encodes + fsync-renames.
        # Every rgg graph has its own padded M, hence its own jitted
        # programs — warm ALL of them first (result cache off, so the
        # timed bursts below still compute) or the first burst eats the
        # compiles and the comparison measures compilation, not writes.
        warm = MappingService(batch_window_s=0.0, cache_entries=0)
        try:
            for g in gs:
                warm.map(g, h, cfg)
        finally:
            warm.close()

        def _burst(store_path):
            kw = {"store_path": store_path} if store_path else {}
            s = MappingService(batch_window_s=0.0, **kw)
            try:
                t0 = time.time()
                for g in gs:
                    s.map(g, h, cfg)
                wall = time.time() - t0
                writes = s.stats()["store"]["writes"] if store_path else 0
            finally:
                s.close()
            return wall, writes

        nostore_s, _ = _burst(None)
        store_s, writes = _burst(f"{root}/store2")
        assert writes == R
        over = (store_s - nostore_s) / R
        emit(f"durability/persist_overhead/{R}x_rgg{n}", store_s * 1e6,
             f"per_write_overhead_us={over*1e6:.0f}")

        section.update({
            "instance": f"rgg{n}",
            "hierarchy": "x".join(map(str, h.a)),
            "cold_compute_s": cold_s,
            "lru_hit_s": lru_s,
            "store_hit_s": warm_s,
            "store_hit_first_restart_s": first_restart_s,
            "store_hit_speedup_vs_cold": cold_s / warm_s,
            "burst_requests": R,
            "burst_no_store_s": nostore_s,
            "burst_with_store_s": store_s,
            "per_write_overhead_s": over,
        })
    finally:
        shutil.rmtree(root, ignore_errors=True)


def bench_coarsen_kernels(scale: str, quick: bool):
    """Device-resident coarsening + the scan-fused v-cycle at scale (PR 9).

    Per instance: stage wall times for the ELL kernels (adjacency build,
    one coarsen level, the full cascade), per-level shrink from the
    O(1)-memory cascade, peak host RSS — and the headline number, the COLD
    path (compile + run, caches cleared) of a full partition call through
    the fused ``coarsen="ell"`` v-cycle vs the PR 8 unrolled
    ``coarsen="segment"`` path. Full runs add a 10^6-vertex cascade-only
    tier (the fused v-cycle's stacked uncoarsening arrays are the memory
    bound there; the cascade carries one graph).
    """
    import resource

    import jax
    from repro.core import graph as G
    from repro.core.coarsen import coarsen_cascade, coarsen_once
    from repro.core.graph import default_ell_deg, ell_adjacency
    from repro.core.multisection import clear_compile_cache
    from repro.core.partition import num_levels, partition_host

    section = BENCH["sections"].setdefault("coarsen_kernels", {})

    def rss_mb():
        return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0

    def cold(fn):
        jax.clear_caches()
        clear_compile_cache()
        t0 = time.time()
        jax.block_until_ready(fn())
        return time.time() - t0

    def warm(fn, reps=3):
        jax.block_until_ready(fn())  # ensure compiled
        best = float("inf")
        for _ in range(reps):
            t0 = time.time()
            jax.block_until_ready(fn())
            best = min(best, time.time() - t0)
        return best

    def levels_telemetry(g, lv, deg):
        ns, ms = coarsen_cascade(g, lv, ell_deg=deg)
        ns, ms = np.asarray(ns), np.asarray(ms)
        per, prev = [], int(g.n)
        for i in range(lv):
            per.append({"n": int(ns[i]), "m": int(ms[i]),
                        "shrink": round(prev / max(int(ns[i]), 1), 3)})
            prev = int(ns[i])
        return per

    side = 100 if quick else 317            # 10^4 / ~10^5 vertices
    insts = [(f"grid{side * side}", G.gen_grid(side))]
    if not quick:
        insts.append(("rgg100k", G.gen_rgg(100_000, seed=1)))
    for gname, g in insts:
        n, m = int(g.n), int(g.m)
        deg = default_ell_deg(n, m)
        lv = num_levels(n, 4)
        row = {"n": n, "m": m, "ell_deg": deg, "levels": lv}

        jf_ell = jax.jit(lambda gg: ell_adjacency(gg, deg)[0])
        t_ell = warm(lambda: jf_ell(g))
        emit(f"coarsen/{gname}/ell_build", t_ell * 1e6, f"deg={deg}")

        jf_once = jax.jit(lambda gg: coarsen_once(gg, salt=1, ell_deg=deg))
        t_once_c = cold(lambda: jf_once(g))
        t_once = warm(lambda: jf_once(g))
        emit(f"coarsen/{gname}/coarsen_once", t_once * 1e6,
             f"cold_s={t_once_c:.2f}")

        t_casc_c = cold(lambda: coarsen_cascade(g, lv, ell_deg=deg))
        t_casc = warm(lambda: coarsen_cascade(g, lv, ell_deg=deg))
        per = levels_telemetry(g, lv, deg)
        emit(f"coarsen/{gname}/cascade{lv}", t_casc * 1e6,
             f"cold_s={t_casc_c:.2f} shrink0={per[0]['shrink']:.2f} "
             f"coarsest_n={per[-1]['n']}")
        row.update({"ell_build_s": t_ell,
                    "coarsen_once_s": t_once,
                    "coarsen_once_cold_s": t_once_c,
                    "cascade_s": t_casc, "cascade_cold_s": t_casc_c,
                    "per_level": per})

        # headline: COLD fused ELL v-cycle vs the PR 8 unrolled segment path
        walls = {}
        for mode in ("ell", "segment"):
            t_c = cold(lambda: partition_host(g, 4, 0.03, "fast", salt=1,
                                              coarsen=mode))
            t_w = warm(lambda: partition_host(g, 4, 0.03, "fast", salt=1,
                                              coarsen=mode), reps=2)
            walls[mode] = {"cold_s": t_c, "warm_s": t_w}
            emit(f"coarsen/{gname}/partition_cold_{mode}", t_c * 1e6,
                 f"warm_s={t_w:.2f}")
        speedup = walls["segment"]["cold_s"] / walls["ell"]["cold_s"]
        emit(f"coarsen/{gname}/fused_cold_speedup",
             walls["ell"]["cold_s"] * 1e6, f"vs_segment={speedup:.2f}x")
        row["partition"] = walls
        row["fused_cold_speedup_vs_segment"] = speedup
        row["peak_rss_mb"] = rss_mb()
        section[gname] = row

    if not quick:
        # 10^6 tier: cascade only (O(1) memory in levels), within container RAM
        g6 = G.gen_grid(1000)
        n6, m6 = int(g6.n), int(g6.m)
        deg6 = default_ell_deg(n6, m6)
        lv6 = num_levels(n6, 4)
        t6_c = cold(lambda: coarsen_cascade(g6, lv6, ell_deg=deg6))
        t6 = warm(lambda: coarsen_cascade(g6, lv6, ell_deg=deg6), reps=2)
        per6 = levels_telemetry(g6, lv6, deg6)
        emit(f"coarsen/grid1000000/cascade{lv6}", t6 * 1e6,
             f"cold_s={t6_c:.2f} shrink0={per6[0]['shrink']:.2f} "
             f"coarsest_n={per6[-1]['n']} rss_mb={rss_mb():.0f}")
        section["grid1000000"] = {
            "n": n6, "m": m6, "ell_deg": deg6, "levels": lv6,
            "cascade_s": t6, "cascade_cold_s": t6_c, "per_level": per6,
            "peak_rss_mb": rss_mb(),
        }


def bench_model_graphs(scale: str, quick: bool):
    """The PR 10 closed loop: HLO → TaskGraph → shared_map on the physical
    chip hierarchy, for real model-zoo archs.

    Per arch: compile a tiny single-device train cell (abstract params),
    extract the per-op communication graph (``launch/comm_graph.py``), map
    it onto ``physical_hierarchy()`` (k=256), and compare ``evaluate_J``
    against the default program-order placement. Extraction and mapping
    walls are COLD (one-shot, compile-dominated) — the gateable cost of
    "map the model you're about to launch". The J improvement must be
    strict: this section doubles as the CI model-graph smoke.
    """
    from repro.core.api import SharedMapConfig, shared_map_direct
    from repro.core.mapping import evaluate_J
    from repro.launch.comm_graph import default_placement, model_comm_graph
    from repro.launch.mesh import physical_hierarchy

    archs = ["whisper-tiny"] if quick else ["whisper-tiny", "xlstm-125m"]
    h = physical_hierarchy(False)
    section = BENCH["sections"].setdefault("model_graphs", {})
    for arch in archs:
        t0 = time.time()
        tg = model_comm_graph(arch, min_tasks=2 * h.k)
        extract_cold_s = time.time() - t0
        g = tg.to_graph()
        t0 = time.time()
        res = shared_map_direct(g, h, SharedMapConfig(preset="fast"))
        map_cold_s = time.time() - t0
        j_def = evaluate_J(g, h, default_placement(tg.n, h.k))
        improvement = j_def / max(res.J, 1e-12)
        assert res.J < j_def, (
            f"{arch}: shared_map J={res.J} did not beat default placement "
            f"J={j_def} — the closed-loop contract is broken")
        emit(f"model_graphs/extract/{arch}", extract_cold_s * 1e6,
             f"tasks={tg.n} edges={tg.m} gran={tg.meta['granularity']}")
        emit(f"model_graphs/map/{arch}", map_cold_s * 1e6,
             f"J={res.J:.3g} J_default={j_def:.3g} "
             f"improvement={improvement:.2f}x")
        section[arch] = {
            "tasks": tg.n, "task_edges": tg.m,
            "granularity": tg.meta["granularity"],
            "fingerprint": tg.fingerprint().hex(),
            "extract_cold_s": extract_cold_s,
            "map_cold_s": map_cold_s,
            "J_sharedmap": res.J,
            "J_default": j_def,
            "improvement": improvement,
            "k": h.k,
        }


SECTIONS = {
    "quality_profiles": bench_quality_profiles,
    "thread_strategies": bench_thread_strategies,
    "presets": bench_presets,
    "scalability": bench_scalability,
    "mapping_vs_default": bench_mapping_vs_default,
    "refine_backends": bench_refine_backends,
    "kernels": bench_kernels,
    "serve": bench_serve,
    "serve_overload": bench_serve_overload,
    "device_pipeline": bench_device_pipeline,
    "durability": bench_durability,
    "coarsen_kernels": bench_coarsen_kernels,
    "model_graphs": bench_model_graphs,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--scale", choices=["small", "large", "paper"], default="small")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(SECTIONS))
    ap.add_argument("--out", default="BENCH_PR10.json",
                    help="telemetry JSON path ('' disables)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    if only and not only <= set(SECTIONS):
        ap.error(f"unknown sections: {sorted(only - set(SECTIONS))}")
    print("name,us_per_call,derived")
    rows_by_section: dict[str, list] = {}
    for name, fn in SECTIONS.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---", flush=True)
        t0 = time.time()
        row_mark = len(ROWS)
        fn(args.scale, args.quick)
        rows_by_section[name] = [
            {"name": n, "us": u, "derived": d} for n, u, d in ROWS[row_mark:]
        ]
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        # each section compiles many (shape x k x preset) programs; drop the
        # jit caches so a long full run stays within host RAM, and the
        # multisection memo/telemetry with them (its compiled executables
        # live inside those jit caches, so hits after a clear would lie).
        import jax
        from repro.core.multisection import clear_compile_cache
        jax.clear_caches()
        clear_compile_cache()
    if args.out:
        # merge into an existing telemetry file: a partial --only run must
        # not wipe the other sections' trajectory data.
        merged = {"sections": {}}
        try:
            with open(args.out) as f:
                merged = json.load(f)
        except (OSError, json.JSONDecodeError):
            pass
        merged.setdefault("sections", {}).update(BENCH["sections"])
        merged["argv"] = sys.argv[1:]
        # rows are merged per section, like sections: a partial run only
        # replaces the rows of the sections it actually ran.
        rows = merged.setdefault("rows", {})
        if isinstance(rows, list):  # pre-merge flat format
            rows = merged["rows"] = {}
        rows.update(rows_by_section)
        with open(args.out, "w") as f:
            json.dump(merged, f, indent=2)
        print(f"# telemetry -> {args.out}", flush=True)


if __name__ == "__main__":
    main()
