"""Model zoo: per-arch smoke tests + decode-vs-forward consistency."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS, SHAPES, cell_applicable, get_config, get_smoke_config
from repro.models import model as M
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    rng = np.random.default_rng(0)
    b = {"tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32),
         "labels": jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.frontend == "vision_stub":
        b["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_patches, cfg.d_model)) * 0.02, jnp.bfloat16)
    if cfg.is_encoder_decoder:
        b["frames"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)) * 0.02, jnp.bfloat16)
        b["tokens"] = b["tokens"][:, :16]
        b["labels"] = b["labels"][:, :16]
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one train step, shapes + no NaNs."""
    cfg = get_smoke_config(arch)
    state = init_train_state(cfg, KEY)
    batch = _batch(cfg)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, total_steps=10)))
    state2, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and 0 < loss < 3 * np.log(cfg.vocab_size)
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                - b.astype(jnp.float32)).sum()),
                     state.params, state2.params))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_shapes(arch):
    cfg = get_smoke_config(arch)
    params = M.init_fn(cfg, KEY)
    B = 2
    cache = M.init_cache(cfg, B, 64)
    logits, cache2 = jax.jit(
        lambda p, t, c, pos: M.decode_fn(cfg, p, t, c, pos))(
        params, jnp.ones((B, 1), jnp.int32), cache, jnp.asarray(0, jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mixtral-8x22b", "xlstm-125m",
                                  "jamba-v0.1-52b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the teacher-forced forward logits —
    the KV-cache/state correctness test (covers full attn, SWA ring buffer,
    xLSTM states, Mamba states, MoE)."""
    cfg = get_smoke_config(arch)
    params = M.init_fn(cfg, KEY)
    B, S = 2, 12
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)

    from repro.models import transformer as tfm
    from repro.models.layers import unembed, apply_norm
    x, _ = tfm.embed_inputs(cfg, params, {"tokens": toks}, None)
    h = tfm.backbone(cfg, params, x, None, remat=False)
    ref_logits = np.asarray(unembed(cfg, params["embed"], h), np.float32)

    cache = M.init_cache(cfg, B, max(S, 16))
    got = []
    for i in range(S):
        logits, cache = M.decode_fn(cfg, params, toks[:, i:i + 1], cache,
                                    jnp.asarray(i, jnp.int32))
        got.append(np.asarray(logits, np.float32))
    got = np.concatenate(got, axis=1)
    np.testing.assert_allclose(got, ref_logits, atol=0.15, rtol=0.1)


def test_sliding_window_ring_buffer():
    """SWA cache is O(window): decoding past the window stays correct."""
    cfg = get_smoke_config("mixtral-8x22b")  # window 16
    params = M.init_fn(cfg, KEY)
    B, S = 1, 24  # > window
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    from repro.models import transformer as tfm
    from repro.models.layers import unembed
    x, _ = tfm.embed_inputs(cfg, params, {"tokens": toks}, None)
    h = tfm.backbone(cfg, params, x, None, remat=False)
    ref_logits = np.asarray(unembed(cfg, params["embed"], h), np.float32)
    cache = M.init_cache(cfg, B, S)
    assert cache["k"].shape[2] == cfg.sliding_window  # O(window) cache
    got = []
    for i in range(S):
        logits, cache = M.decode_fn(cfg, params, toks[:, i:i + 1], cache,
                                    jnp.asarray(i, jnp.int32))
        got.append(np.asarray(logits, np.float32))
    np.testing.assert_allclose(np.concatenate(got, 1), ref_logits, atol=0.15, rtol=0.1)


def test_param_counts_match_configs():
    """Full configs instantiate abstractly at the published scale."""
    expect = {
        "qwen2-72b": (60e9, 90e9),
        "qwen1.5-110b": (90e9, 130e9),
        "mixtral-8x22b": (120e9, 160e9),
        "llama3.2-3b": (2.5e9, 4.5e9),
        "command-r-plus-104b": (85e9, 120e9),
        "jamba-v0.1-52b": (40e9, 60e9),
        "xlstm-125m": (0.08e9, 0.2e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        abs_params = jax.eval_shape(lambda c=cfg: M.init_fn(c, KEY))
        n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abs_params))
        assert lo < n < hi, f"{arch}: {n/1e9:.1f}B params out of range"
        # analytic count used by the roofline agrees within 15%
        assert abs(cfg.param_count() - n) / n < 0.15, (arch, cfg.param_count(), n)


def test_input_specs_cover_all_cells():
    for arch in ARCHS:
        cfg = get_config(arch)
        for cell in SHAPES:
            ok, _ = cell_applicable(cfg, cell)
            if not ok:
                continue
            specs = M.input_specs(cfg, cell.seq_len, cell.global_batch, cell.mode)
            assert all(isinstance(s, jax.ShapeDtypeStruct) for s in jax.tree.leaves(specs))


def test_slstm_time_chunk_exact():
    """Chunked sLSTM (HBM-traffic knob) is bitwise-equivalent to step-wise."""
    import jax.numpy as jnp
    from repro.models import xlstm as xl
    cfg = get_smoke_config("xlstm-125m")
    p = xl.slstm_params(cfg, KEY)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16, cfg.d_model)) * 0.1,
                    jnp.float32)
    a = xl.apply_slstm(cfg, p, x, time_chunk=1)
    b = xl.apply_slstm(cfg, p, x, time_chunk=4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
