"""Supervised worker pool (serve/supervisor.py): crash detection, restart,
re-dispatch, and future-resolution guarantees — all on cheap echo tasks
(no jax in the workers' task path)."""
import os
import signal
import time

import pytest

from repro.faults import FaultInjector
from repro.serve.supervisor import (SupervisedWorkerPool, WorkerCrashError,
                                    WorkerPoolClosedError)

ECHO = "repro.serve.supervisor:echo_task"
FAST = {"restart_backoff_s": 0.01, "poll_s": 0.01}


def test_roundtrip_and_error_propagation():
    with SupervisedWorkerPool(2, **FAST) as pool:
        futs = [pool.submit(ECHO, {"x": i}) for i in range(8)]
        assert sorted(f.result(timeout=60)["x"] for f in futs) == list(range(8))
        bad = pool.submit(ECHO, {"raise": "kaboom"})
        with pytest.raises(ValueError, match="kaboom"):
            bad.result(timeout=60)
        s = pool.stats()
        assert s["ok"] == 8 and s["err"] == 1 and s["crashes"] == 0


def test_injected_sigkill_mid_request_future_still_resolves():
    inj = FaultInjector(fail_at={"worker_kill": (0,)})
    with SupervisedWorkerPool(2, fault_injector=inj, **FAST) as pool:
        fut = pool.submit(ECHO, {"x": 7, "sleep_s": 0.3})
        assert fut.result(timeout=60)["x"] == 7  # zero unresolved futures
        s = pool.stats()
        assert s["killed_injected"] == 1
        assert s["crashes"] >= 1
        assert s["restarts"] >= 1
        assert s["redispatched"] >= 1
        assert inj.fired and inj.fired[0][0] == "worker_kill"


def test_external_sigkill_detected_and_restarted():
    with SupervisedWorkerPool(1, **FAST) as pool:
        # a task that kills its own worker once: the pool must restart the
        # slot and the re-dispatched copy (which kills again...) must
        # eventually exhaust — but here we kill externally instead, with a
        # benign task in flight.
        fut = pool.submit(ECHO, {"x": 1, "sleep_s": 1.0})
        deadline = time.monotonic() + 10
        pid = None
        while time.monotonic() < deadline and pid is None:
            w = pool._workers[0]
            if w.task is not None and w.alive():
                pid = w.proc.pid
            else:
                time.sleep(0.01)
        assert pid is not None
        os.kill(pid, signal.SIGKILL)
        assert fut.result(timeout=60)["x"] == 1
        s = pool.stats()
        assert s["crashes"] >= 1 and s["redispatched"] >= 1


def test_repeat_crasher_fails_typed_and_transient():
    with SupervisedWorkerPool(1, max_redispatch=1, **FAST) as pool:
        fut = pool.submit(ECHO, {"die": True})
        with pytest.raises(WorkerCrashError) as ei:
            fut.result(timeout=120)
        assert ei.value.transient is True  # feeds the service retry ladder
        assert ei.value.redispatches == 1
        s = pool.stats()
        assert s["crash_failed"] == 1 and s["crashes"] >= 2
        # the pool survives its crasher: a clean task still runs
        assert pool.submit(ECHO, {"x": 5}).result(timeout=60)["x"] == 5


def test_restart_backoff_is_capped_exponential():
    with SupervisedWorkerPool(1, max_redispatch=3, restart_backoff_s=0.05,
                              restart_backoff_cap_s=0.1, poll_s=0.01) as pool:
        fut = pool.submit(ECHO, {"die": True})
        with pytest.raises(WorkerCrashError):
            fut.result(timeout=120)
        w = pool._workers[0]
        assert w.consecutive_crashes >= 4
        # a completed task resets the crash streak
        assert pool.submit(ECHO, {"x": 1}).result(timeout=60)["x"] == 1
        assert pool._workers[0].consecutive_crashes == 0


def test_close_fails_pending_futures():
    pool = SupervisedWorkerPool(1, **FAST)
    slow = pool.submit(ECHO, {"sleep_s": 30})
    queued = pool.submit(ECHO, {"x": 2})
    pool.close(wait=False)
    with pytest.raises(WorkerPoolClosedError):
        queued.result(timeout=10)
    with pytest.raises(WorkerPoolClosedError):
        slow.result(timeout=10)
    with pytest.raises(WorkerPoolClosedError):
        pool.submit(ECHO, {"x": 3})


def test_burst_with_random_kills_all_futures_resolve():
    """The acceptance criterion at pool level: under repeated injected
    SIGKILLs, every submitted future resolves (result or typed error)."""
    inj = FaultInjector(fail_at={"worker_kill": (1, 3, 5)})
    with SupervisedWorkerPool(2, fault_injector=inj, max_redispatch=3,
                              **FAST) as pool:
        futs = [pool.submit(ECHO, {"x": i, "sleep_s": 0.05})
                for i in range(12)]
        done = 0
        for f in futs:
            try:
                f.result(timeout=120)
                done += 1
            except WorkerCrashError:
                done += 1  # typed resolution still counts as resolved
        assert done == 12
        assert all(f.done() for f in futs)
