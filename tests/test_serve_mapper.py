"""Mapping service: coalescing, result cache, warmup, bit-identity."""
import asyncio
import time

import numpy as np
import pytest

from repro.core import graph as G
from repro.core.api import (SharedMapConfig, current_service, shared_map,
                            shared_map_direct)
from repro.core.hierarchy import Hierarchy
from repro.serve.mapper import MappingService, request_fingerprint

H = Hierarchy(a=(4, 2), d=(1.0, 10.0))
CFG = SharedMapConfig(preset="fast")


@pytest.fixture(scope="module")
def graphs():
    return [G.gen_rgg(300, seed=40 + i) for i in range(4)]


@pytest.fixture()
def svc():
    s = MappingService()
    yield s
    s.close()


def test_solo_request_bit_identical(graphs, svc):
    d = shared_map_direct(graphs[0], H, CFG)
    r = svc.map(graphs[0], H, CFG)
    assert np.array_equal(d.pe_of, r.pe_of)
    assert d.J == r.J


def test_concurrent_requests_bit_identical_and_coalesced(graphs, svc):
    """Cross-request merging must not change any request's result — vmap
    lanes are independent — and must actually merge dispatches."""
    direct = [shared_map_direct(g, H, CFG) for g in graphs]
    futs = [svc.submit(g, H, CFG) for g in graphs]
    res = [f.result(timeout=600) for f in futs]
    for d, r in zip(direct, res):
        assert np.array_equal(d.pe_of, r.pe_of)
        assert d.J == r.J
    co = svc.stats()["coalesce"]
    assert co["groups"] > co["dispatches"], co  # merging happened


def test_result_cache_hit_fast_and_identical(graphs, svc):
    first = svc.map(graphs[0], H, CFG)
    assert first.stats["result_cache"]["hit"] is False
    t0 = time.time()
    again = svc.map(graphs[0], H, CFG)
    hit_s = time.time() - t0
    assert again.stats["result_cache"]["hit"] is True
    assert np.array_equal(first.pe_of, again.pe_of)
    assert again.J == first.J
    assert hit_s < 0.1  # microseconds-scale in practice; generous CI bound
    # a different seed is a different request
    other = svc.map(graphs[0], H, SharedMapConfig(preset="fast", seed=3))
    assert other.stats["result_cache"]["hit"] is False


def test_result_cache_lru_bound(graphs):
    svc = MappingService(cache_entries=2)
    try:
        for g in graphs[:3]:
            svc.map(g, H, CFG)
        st = svc.stats()["result_cache"]
        assert st["entries"] == 2
        assert st["evictions"] == 1
        # oldest entry was evicted -> recompute (miss)
        r = svc.map(graphs[0], H, CFG)
        assert r.stats["result_cache"]["hit"] is False
    finally:
        svc.close()


def test_inflight_dedup(graphs, svc):
    """Identical concurrent requests coalesce onto ONE computation."""
    futs = [svc.submit(graphs[1], H, CFG) for _ in range(3)]
    res = [f.result(timeout=600) for f in futs]
    for r in res[1:]:
        assert np.array_equal(res[0].pe_of, r.pe_of)
    assert svc.stats()["inflight_dedup"] >= 2


def test_fingerprint_ignores_padding(graphs):
    g = graphs[0]
    padded = G.pad_graph(g, g.N * 2, g.M * 2)
    assert request_fingerprint(g, H, CFG) == request_fingerprint(padded, H, CFG)
    assert request_fingerprint(g, H, CFG) != request_fingerprint(
        g, H, SharedMapConfig(preset="fast", seed=1))


def test_shared_map_routing(graphs):
    d = shared_map(graphs[2], H, CFG)  # no service installed
    with MappingService() as svc:
        assert current_service() is svc
        r = shared_map(graphs[2], H, CFG)
        assert "result_cache" in r.stats
        assert np.array_equal(d.pe_of, r.pe_of)
    assert current_service() is None


def test_fallback_strategies_supported(graphs, svc):
    cfg = SharedMapConfig(preset="fast", strategy="queue")
    d = shared_map_direct(graphs[3], H, cfg)
    r = svc.map(graphs[3], H, cfg)
    assert np.array_equal(d.pe_of, r.pe_of)
    # cached on repeat like any other request
    again = svc.map(graphs[3], H, cfg)
    assert again.stats["result_cache"]["hit"] is True


def test_amap_asyncio(graphs, svc):
    async def run():
        return await asyncio.gather(
            *(svc.amap(g, H, CFG) for g in graphs[:2]))

    res = asyncio.run(run())
    direct = [shared_map_direct(g, H, CFG) for g in graphs[:2]]
    for d, r in zip(direct, res):
        assert np.array_equal(d.pe_of, r.pe_of)


def test_warmup_precompiles(svc):
    """A dispatch whose (shape, k, batch) was warmed is a pure program-cache
    hit — no new XLA compile."""
    from repro.core.multisection import PlanGroup, execute_group_batch
    from repro.core.partition import num_levels
    from repro.serve.mapper import _dummy_host_graph

    N, M, k, B = 1024, 8192, 4, 2  # unique shape: not used by other tests
    w = svc.warmup(shapes=[(N, M)], ks=[k], preset="fast", batch_sizes=(B,))
    assert w["programs"] == 1
    hg = _dummy_host_graph(N, M)
    gr = PlanGroup(members=[hg] * B, N=N, M=M, arity=k,
                   levels=num_levels(N, k), preset="fast", backend="xla",
                   deg=None, eps=[0.03] * B, salts=[0, 1])
    stats = {"hits": 0, "misses": 0}
    execute_group_batch([gr], stats)
    assert stats == {"hits": 1, "misses": 0}


# --- PR7: device-resident strategy through the service ------------------------

def test_device_strategy_via_service(graphs, svc):
    """The device strategy is plannable: served requests must be
    bit-identical to the direct path, like bucket/layer."""
    cfg = SharedMapConfig(preset="fast", strategy="device")
    d = shared_map_direct(graphs[0], H, cfg)
    r = svc.map(graphs[0], H, cfg)
    assert np.array_equal(d.pe_of, r.pe_of)
    assert d.J == r.J
    again = svc.map(graphs[0], H, cfg)
    assert again.stats["result_cache"]["hit"] is True


def test_device_requests_coalesce(graphs):
    """Same-shape device-strategy requests share exec keys level by level,
    so a concurrent burst merges into shared dispatches — and merging must
    not change any request's labels."""
    cfgs = [SharedMapConfig(preset="fast", strategy="device", seed=s)
            for s in (1, 2, 3)]  # same graph: identical root (N0, M0) keys
    direct = [shared_map_direct(graphs[0], H, c) for c in cfgs]
    svc = MappingService(cache_entries=0)
    try:
        futs = svc.submit_many([(graphs[0], H, c) for c in cfgs])
        res = [f.result(timeout=600) for f in futs]
        co = svc.stats()["coalesce"]
    finally:
        svc.close()
    for d, r in zip(direct, res):
        assert np.array_equal(d.pe_of, r.pe_of)
    assert co["groups"] > co["dispatches"], co


def test_device_single_fetch_through_service(graphs):
    """The single-device-fetch contract survives the service plumbing: one
    array fetch for the multisection labels per request (evaluate_J of the
    final result is a separate, documented scalar read)."""
    from repro.core.multisection import (reset_transfer_stats,
                                         transfer_stats)

    cfg = SharedMapConfig(preset="fast", strategy="device")
    svc = MappingService(cache_entries=0)
    try:
        svc.map(graphs[1], H, cfg)  # warm compiles
        reset_transfer_stats()
        svc.map(graphs[1], H, cfg)
        xf = transfer_stats()
    finally:
        svc.close()
    assert xf["d2h_array_fetches"] == 1, xf


def test_submit_after_close_raises():
    svc = MappingService()
    svc.close()
    with pytest.raises(RuntimeError):
        svc.submit(G.gen_rgg(50, seed=1), H, CFG)
