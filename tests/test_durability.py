"""Service-level durability: warm-restart from the persistent store,
on-disk corruption containment, supervised worker-mode integration, and
shadow verification of the device pipeline (DESIGN.md §12)."""
import os
import time

import numpy as np
import pytest

from repro.core import api as capi
from repro.core.api import SharedMapConfig, shared_map_direct
from repro.core.graph import from_edges
from repro.core.hierarchy import Hierarchy
from repro.faults import FaultInjector
from repro.serve.mapper import MappingService, request_fingerprint
from repro.serve.tracker import InMemoryTracker

H = Hierarchy(a=(2, 2), d=(1.0, 10.0))
CFG = SharedMapConfig(preset="fast")


def _ring(n=48, seed=0):
    u = np.arange(n - 1)
    return from_edges(n, u, u + 1)


def _svc(**kw):
    kw.setdefault("batch_window_s", 0.0)
    return MappingService(**kw)


# ---------------------------------------------------------------- store tier


def test_warm_restart_reloads_bit_identical(tmp_path):
    g = _ring()
    path = str(tmp_path / "store")
    svc = _svc(store_path=path)
    cold = svc.submit(g, H, CFG).result(timeout=120)
    svc.close()

    svc2 = _svc(store_path=path)  # a "restarted process"
    warm = svc2.submit(g, H, CFG).result(timeout=120)
    s = svc2.stats()
    svc2.close()
    assert np.array_equal(cold.pe_of, warm.pe_of)
    assert warm.pe_of.dtype == cold.pe_of.dtype
    assert cold.J == warm.J
    assert warm.stats["result_cache"]["hit"] is True
    assert s["store"]["hits"] == 1
    assert s["store"]["entries_on_open"] >= 1


def test_store_shared_between_live_services(tmp_path):
    """Two services over one directory: what one computes, the other
    serves from the persistence tier (the multi-process cache-sharing
    story, minus the processes)."""
    g = _ring(seed=1)
    path = str(tmp_path / "store")
    with _svc(store_path=path) as a, _svc(store_path=path) as b:
        ra = a.submit(g, H, CFG).result(timeout=120)
        rb = b.submit(g, H, CFG).result(timeout=120)
        assert np.array_equal(ra.pe_of, rb.pe_of)
        assert b.stats()["store"]["hits"] == 1


def test_corrupt_store_entry_recomputed_service_stays_up(tmp_path):
    g = _ring()
    path = str(tmp_path / "store")
    svc = _svc(store_path=path)
    first = svc.submit(g, H, CFG).result(timeout=120)
    svc.close()

    fp = request_fingerprint(g, H, CFG)
    entry = os.path.join(path, fp.hex() + ".res")
    blob = bytearray(open(entry, "rb").read())
    blob[len(blob) // 2] ^= 0x01  # single bit flip
    with open(entry, "wb") as f:
        f.write(bytes(blob))

    svc2 = _svc(store_path=path)
    res = svc2.submit(g, H, CFG).result(timeout=120)  # recomputed, not served
    s = svc2.stats()
    # the service survives AND the recompute matches the original
    again = svc2.submit(_ring(seed=2), H, CFG).result(timeout=120)
    svc2.close()
    assert np.array_equal(res.pe_of, first.pe_of)
    assert s["store"]["corrupt"] == 1
    assert s["store"]["quarantined"] == 1
    assert res.stats["result_cache"]["hit"] is False
    assert again.pe_of.shape[0] >= 1


def test_torn_write_injection_roundtrip(tmp_path):
    """A torn (injected) store write is detected on the NEXT service's
    load and degrades to recompute — never a wrong answer."""
    g = _ring()
    path = str(tmp_path / "store")
    inj = FaultInjector(fail_at={"store_write": (0,)})
    svc = _svc(store_path=path, fault_injector=inj)
    first = svc.submit(g, H, CFG).result(timeout=120)
    svc.close()
    assert ("store_write", 0) in inj.fired

    svc2 = _svc(store_path=path)
    res = svc2.submit(g, H, CFG).result(timeout=120)
    s = svc2.stats()
    svc2.close()
    assert np.array_equal(res.pe_of, first.pe_of)
    assert s["store"]["corrupt"] == 1
    assert res.stats["result_cache"]["hit"] is False


def test_degraded_results_not_persisted(tmp_path):
    """The degradation ladder must never poison the durable tier."""
    g = _ring()
    path = str(tmp_path / "store")
    inj = FaultInjector(fail_at={"dispatch": tuple(range(8))})
    svc = _svc(store_path=path, fault_injector=inj,
               retry=None, degrade_on_failure=True)
    res = svc.submit(g, H, CFG).result(timeout=120)
    s = svc.stats()
    svc.close()
    assert res.stats["degradation"]["level"] > 0
    assert s["store"]["writes"] == 0
    assert s["store"]["entries"] == 0


# ----------------------------------------------------- supervised worker mode


@pytest.mark.slow
def test_worker_mode_clean_and_sigkill_recovery(tmp_path):
    """One combined integration test (worker spawn is expensive):
    (1) a clean worker-mode request is bit-identical to the direct path;
    (2) a SIGKILLed worker mid-request is restarted and the request
        re-dispatched — the future STILL resolves, bit-identically."""
    g = _ring()
    ref = shared_map_direct(g, H, CFG)
    inj = FaultInjector(fail_at={"worker_kill": (1,)})
    tr = InMemoryTracker()
    svc = _svc(workers=1, fault_injector=inj, tracker=tr,
               store_path=str(tmp_path / "store"),
               worker_kwargs={"restart_backoff_s": 0.01})
    try:
        clean = svc.submit(g, H, CFG).result(timeout=300)
        assert np.array_equal(clean.pe_of, ref.pe_of)
        assert clean.J == ref.J

        # occurrence 1 of worker_kill fires on the next dispatch: the
        # worker is SIGKILLed with the request in flight.
        cfg2 = SharedMapConfig(preset="fast", seed=7)
        ref2 = shared_map_direct(g, H, cfg2)
        killed = svc.submit(g, H, cfg2).result(timeout=300)
        assert np.array_equal(killed.pe_of, ref2.pe_of)
        s = svc.stats()
        assert s["workers"]["killed_injected"] == 1
        assert s["workers"]["crashes"] >= 1
        assert s["workers"]["restarts"] >= 1
        assert s["workers"]["redispatched"] >= 1
        assert s["store"]["writes"] == 2  # both results persisted
        assert any(e["name"] == "worker_crash" for e in tr.events)
    finally:
        svc.close()


# ------------------------------------------------------- shadow verification


def test_shadow_match_keeps_device_live():
    g = _ring()
    dcfg = SharedMapConfig(preset="fast", strategy="device")
    svc = _svc(shadow_verify_fraction=1.0)
    res = svc.submit(g, H, dcfg).result(timeout=300)
    svc.close(wait=True)  # drains the fallback pool -> shadow job done
    s = svc.stats()
    assert res.stats.get("resident") is not False
    assert s["shadow"]["sampled"] == 1
    assert s["shadow"]["matched"] == 1
    assert s["shadow"]["mismatched"] == 0
    assert s["shadow"]["device_quarantined"] is False


def test_shadow_mismatch_quarantines_device(tmp_path, monkeypatch):
    """Force a divergence by making the host-ref twin disagree: the
    service must record the mismatch, evict + quarantine the entry, and
    route every later device request to the host path."""
    g = _ring()
    dcfg = SharedMapConfig(preset="fast", strategy="device")
    tr = InMemoryTracker()
    svc = _svc(shadow_verify_fraction=1.0, tracker=tr,
               store_path=str(tmp_path / "store"))
    orig = capi.shared_map_direct

    def lying(g_, h_, cfg_, checkpoint=None, resident=None):
        res = orig(g_, h_, cfg_, checkpoint=checkpoint, resident=resident)
        if resident is False:  # only the shadow twin lies
            res.pe_of = (res.pe_of + 1) % int(h_.k)
        return res

    monkeypatch.setattr(capi, "shared_map_direct", lying)
    try:
        svc.submit(g, H, dcfg).result(timeout=300)
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            if svc.stats()["shadow"]["mismatched"]:
                break
            time.sleep(0.05)
        s = svc.stats()
        assert s["shadow"]["mismatched"] == 1
        assert s["shadow"]["device_quarantined"] is True
        assert s["store"]["quarantined"] == 1  # the lying entry is evicted
        assert any(e["name"] == "shadow_mismatch" for e in tr.events)
        # later device requests run the host-ref twin
        monkeypatch.setattr(capi, "shared_map_direct", orig)
        later = svc.submit(g, H, SharedMapConfig(
            preset="fast", strategy="device", seed=3)).result(timeout=300)
        assert later.stats.get("resident") is False
        # and no further shadow sampling happens while quarantined
        assert svc.stats()["shadow"]["sampled"] == 1
    finally:
        svc.close()


def test_shadow_fraction_zero_never_samples():
    g = _ring()
    dcfg = SharedMapConfig(preset="fast", strategy="device")
    with _svc() as svc:
        svc.submit(g, H, dcfg).result(timeout=300)
    assert svc.stats()["shadow"]["sampled"] == 0
