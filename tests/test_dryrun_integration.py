"""Multi-pod dry-run integration: lower+compile a real cell under 512
forced host devices, in a SUBPROCESS (so the main test process keeps its
single-device backend). Marked slow; the full 40-cell x 2-mesh sweep is
run via `python -m repro.launch.dryrun --all` (results in EXPERIMENTS.md)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_dryrun_cell_compiles(tmp_path):
    out = tmp_path / "dryrun.jsonl"
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "llama3.2-3b", "--shape", "decode_32k", "--mesh", "pod2",
         "--out", str(out)],
        env=env, capture_output=True, text=True, timeout=1200, cwd=ROOT)
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.loads(out.read_text().strip().splitlines()[-1])
    assert "error" not in rec, rec.get("error")
    assert rec["chips"] == 512
    assert rec["memory"]["per_device_total"] > 0
    assert rec["hlo"]["flops_per_device"] > 0
    assert rec["roofline"]["dominant"] in ("compute_s", "memory_s", "collective_s")


@pytest.mark.slow
def test_dryrun_device_order_sharedmap(tmp_path):
    """The SharedMap-ordered mesh builds and compiles too."""
    script = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=512'\n"
        "import jax, jax.numpy as jnp\n"
        "from repro.launch.mesh import make_production_mesh\n"
        "from jax.sharding import NamedSharding, PartitionSpec as P\n"
        "mesh = make_production_mesh(multi_pod=True, device_order='sharedmap')\n"
        "x = jax.ShapeDtypeStruct((512, 64), jnp.float32,\n"
        "    sharding=NamedSharding(mesh, P(('pod','data'), 'model')))\n"
        "c = jax.jit(lambda a: (a * 2).sum()).lower(x).compile()\n"
        "ca = c.cost_analysis()\n"
        "ca = ca[0] if isinstance(ca, (list, tuple)) else ca\n"  # jax<0.5
        "print('OK', ca['flops'])\n"
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600, cwd=ROOT)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout
