"""Crash-safe result store (serve/store.py): roundtrip bit-identity,
corruption detection + quarantine, torn-write injection, stats."""
import os

import numpy as np
import pytest

from repro.core.api import SharedMapResult
from repro.faults import FaultInjector
from repro.serve.store import (CorruptEntryError, ResultStore, decode_entry,
                               encode_entry)

FP = bytes(range(16))
GFP = bytes(range(16, 32))


def _result(n=32, k=4, seed=0):
    rng = np.random.default_rng(seed)
    return SharedMapResult(
        pe_of=rng.integers(0, k, size=n).astype(np.int32),
        J=float(rng.uniform(0, 100)),
        stats={"strategy": "device", "levels": [{"k": k}],
               "partition_calls": 3})


def test_roundtrip_bit_identical(tmp_path):
    st = ResultStore(str(tmp_path / "store"))
    res = _result()
    assert st.put(FP, GFP, res)
    out = st.get(FP)
    assert out is not None
    got, gfp = out
    assert gfp == GFP
    assert got.pe_of.dtype == res.pe_of.dtype
    assert np.array_equal(got.pe_of, res.pe_of)
    assert got.J == res.J
    assert got.stats["strategy"] == "device"
    assert got.stats["partition_calls"] == 3
    s = st.stats()
    assert s["writes"] == 1 and s["hits"] == 1 and s["corrupt"] == 0


def test_missing_entry_is_a_miss(tmp_path):
    st = ResultStore(str(tmp_path / "store"))
    assert st.get(FP) is None
    assert st.stats()["misses"] == 1


def test_persists_across_instances(tmp_path):
    path = str(tmp_path / "store")
    ResultStore(path).put(FP, GFP, _result())
    st2 = ResultStore(path)
    assert st2.stats()["entries_on_open"] == 1
    out = st2.get(FP)
    assert out is not None
    assert np.array_equal(out[0].pe_of, _result().pe_of)


def test_truncated_entry_quarantined_never_served(tmp_path):
    st = ResultStore(str(tmp_path / "store"))
    st.put(FP, GFP, _result())
    path = st._entry_path(FP)
    blob = open(path, "rb").read()
    for cut in (0, 3, 10, len(blob) // 2, len(blob) - 1):
        with open(path, "wb") as f:
            f.write(blob[:cut])
        assert st.get(FP) is None, f"truncation at {cut} was served"
        # quarantined: the broken file is GONE from the serving set
        assert not os.path.exists(path)
        st.put(FP, GFP, _result())  # re-publish for the next cut
    s = st.stats()
    assert s["corrupt"] == 5 and s["quarantined"] == 5


def test_bitflip_quarantined_never_served(tmp_path):
    st = ResultStore(str(tmp_path / "store"))
    st.put(FP, GFP, _result())
    path = st._entry_path(FP)
    blob = bytearray(open(path, "rb").read())
    blob[len(blob) // 2] ^= 0x40  # flip one bit mid-payload
    with open(path, "wb") as f:
        f.write(bytes(blob))
    assert st.get(FP) is None
    s = st.stats()
    assert s["corrupt"] == 1 and s["quarantined"] == 1
    # forensic copy + reason file land in quarantine/
    qfiles = os.listdir(st.quarantine_dir)
    assert FP.hex() + ".res" in qfiles
    reason = open(os.path.join(st.quarantine_dir,
                               FP.hex() + ".res.reason")).read()
    assert "checksum" in reason


def test_wrong_magic_and_version_rejected():
    res = _result()
    blob = encode_entry(FP, GFP, res)
    with pytest.raises(CorruptEntryError):
        decode_entry(b"XXXX" + blob[4:], FP)
    with pytest.raises(CorruptEntryError):
        decode_entry(blob, GFP)  # fingerprint/key mismatch
    decode_entry(blob, FP)  # sanity: the untouched blob parses


def test_torn_write_injection_detected_on_load(tmp_path):
    inj = FaultInjector(fail_at={"store_write": (0,)})
    st = ResultStore(str(tmp_path / "store"), fault_injector=inj)
    assert st.put(FP, GFP, _result())  # published, but torn
    assert st.get(FP) is None
    assert st.stats()["corrupt"] == 1
    # the second write is clean (fail_at fires once) and serves fine
    assert st.put(FP, GFP, _result())
    assert st.get(FP) is not None


def test_tmp_files_swept_on_open(tmp_path):
    path = str(tmp_path / "store")
    st = ResultStore(path)
    orphan = os.path.join(st._tmp_dir, "deadbeef.123.1")
    with open(orphan, "wb") as f:
        f.write(b"partial")
    st2 = ResultStore(path)
    assert not os.path.exists(orphan)
    assert st2.stats()["entries_on_open"] == 0
