"""The PR 10 closed loop, as a tier-1 contract: compile a model-zoo arch,
extract its HLO communication graph, map it onto the physical chip
hierarchy, and beat the default program-order placement — strictly.

One arch (whisper-tiny: the zoo's smallest, ~1 min total) keeps the suite
tractable; `benchmarks/run.py --only model_graphs` runs the wider sweep.
"""
import numpy as np
import pytest

from repro.core.api import SharedMapConfig, shared_map_direct
from repro.core.mapping import evaluate_J
from repro.launch.comm_graph import default_placement, model_comm_graph
from repro.launch.mesh import physical_hierarchy


@pytest.fixture(scope="module")
def whisper_tg():
    h = physical_hierarchy(False)
    return model_comm_graph("whisper-tiny", min_tasks=2 * h.k)


def test_extracted_graph_is_mappable(whisper_tg):
    tg = whisper_tg
    h = physical_hierarchy(False)
    assert tg.n >= 2 * h.k  # min_tasks escalated to op granularity
    assert tg.meta["granularity"] == "op"
    assert tg.meta["source"] == "hlo" and tg.meta["arch"] == "whisper-tiny"
    assert tg.m > 0 and float(tg.w.min()) > 0
    assert float(tg.vwgt.max()) > 1.0  # the dots carry real FLOP weights
    # extraction is deterministic: same compile -> same fingerprint
    tg2 = model_comm_graph("whisper-tiny", min_tasks=2 * h.k)
    assert tg2.fingerprint() == tg.fingerprint()


def test_closed_loop_beats_default_placement(whisper_tg):
    tg = whisper_tg
    h = physical_hierarchy(False)
    g = tg.to_graph()
    res = shared_map_direct(g, h, SharedMapConfig(preset="fast"))
    j_default = evaluate_J(g, h, default_placement(tg.n, h.k))
    assert res.J < j_default, (res.J, j_default)
    # sanity: the mapping is a real assignment over all k PEs' range
    assert res.pe_of.shape == (int(g.N),)
    assert 0 <= int(res.pe_of.min()) and int(res.pe_of[:tg.n].max()) < h.k


def test_default_placement_shape():
    p = default_placement(10, 4)
    assert p.tolist() == [0, 0, 0, 1, 1, 2, 2, 2, 3, 3]
    assert np.array_equal(np.unique(p), np.arange(4))
