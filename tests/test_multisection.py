"""Hierarchical multisection: the paper's core (§4, §5) + baselines."""
import numpy as np
import pytest

from repro.core import graph as G
from repro.core.api import SharedMapConfig, shared_map
from repro.core.baselines import (global_multisection, identity_mapping,
                                  kaffpa_map_style, random_mapping)
from repro.core.hierarchy import Hierarchy
from repro.core.mapping import evaluate_J
from repro.core.multisection import STRATEGIES, hierarchical_multisection

H_PAPER = Hierarchy(a=(4, 2, 3), d=(1.0, 10.0, 100.0))  # Fig 1


@pytest.fixture(scope="module")
def g():
    return G.gen_rgg(2500, seed=7)


def _balance(g, pe_of, k, eps):
    bw = np.bincount(pe_of, weights=np.asarray(g.vwgt)[: int(g.n)], minlength=k)
    Lmax = (1 + eps) * float(g.total_weight()) / k
    return bw, Lmax, bool((bw <= Lmax + 1e-4).all())


def test_final_partition_eps_balanced(g):
    res = shared_map(g, H_PAPER, SharedMapConfig(eps=0.03, preset="fast"))
    bw, Lmax, ok = _balance(g, res.pe_of, H_PAPER.k, 0.03)
    assert ok, (bw.max(), Lmax)
    assert (bw > 0).all(), "idle PE"


def test_beats_naive_mappings(g):
    res = shared_map(g, H_PAPER, SharedMapConfig(eps=0.03, preset="fast"))
    j_rand = evaluate_J(g, H_PAPER, random_mapping(g, H_PAPER))
    j_ident = evaluate_J(g, H_PAPER, identity_mapping(g, H_PAPER))
    assert res.J < 0.5 * j_rand
    assert res.J < j_ident


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_all_strategies_valid(g, strategy):
    res = shared_map(g, H_PAPER, SharedMapConfig(eps=0.03, preset="fast",
                                                 strategy=strategy))
    bw, Lmax, ok = _balance(g, res.pe_of, H_PAPER.k, 0.03)
    assert ok


def test_strategies_agree_on_quality(g):
    js = {}
    for s in STRATEGIES:
        js[s] = shared_map(g, H_PAPER, SharedMapConfig(eps=0.03, preset="fast",
                                                       strategy=s)).J
    base = min(js.values())
    for s, j in js.items():
        assert j <= 1.25 * base, js  # same algorithm modulo padding effects


def test_strategy_determinism(g):
    a = shared_map(g, H_PAPER, SharedMapConfig(preset="fast", strategy="bucket", seed=4))
    b = shared_map(g, H_PAPER, SharedMapConfig(preset="fast", strategy="bucket", seed=4))
    assert np.array_equal(a.pe_of, b.pe_of)


def test_adaptive_beats_fixed_eps_on_balance():
    """GM (fixed eps) can exceed L_max where SharedMap cannot (paper §5/§6.4)."""
    g = G.gen_rgg(1200, seed=3)
    h = Hierarchy(a=(4, 4), d=(1.0, 10.0))
    viol_adaptive = 0
    for seed in range(3):
        res = hierarchical_multisection(g, h, eps=0.03, preset="fast",
                                        seed=seed, adaptive=True)
        _, _, ok = _balance(g, res.pe_of, h.k, 0.03)
        viol_adaptive += (not ok)
    assert viol_adaptive == 0


def test_kaffpa_map_style_baseline(g):
    h = Hierarchy(a=(4, 2, 2), d=(1.0, 10.0, 100.0))  # k=16 (power of two)
    res = kaffpa_map_style(g, h, eps=0.05, preset="fast")
    bw, Lmax, ok = _balance(g, res.pe_of, h.k, 0.05)
    assert ok
    j = evaluate_J(g, h, res.pe_of)
    j_rand = evaluate_J(g, h, random_mapping(g, h))
    assert j < j_rand


def test_global_multisection_baseline(g):
    res = global_multisection(g, H_PAPER, eps=0.03, preset="fast")
    j = evaluate_J(g, H_PAPER, res.pe_of)
    j_rand = evaluate_J(g, H_PAPER, random_mapping(g, H_PAPER))
    assert j < j_rand


def test_sharedmap_quality_vs_baselines(g):
    """The paper's mechanism claim, isolated: with EQUAL mapping-phase
    machinery (both sides get the swap pass — our substrate partitioner is
    weaker than KaFFPa, so unlike the paper it needs one), adaptive-eps
    hierarchical multisection is competitive-or-better vs GM's fixed-eps.
    The 60/40 best-solution split lives in benchmarks/quality_profiles."""
    h = H_PAPER
    j_sm = shared_map(g, h, SharedMapConfig(eps=0.03, preset="strong",
                                            refine_mapping=True)).J
    j_gm = evaluate_J(g, h, global_multisection(g, h, 0.03, "strong").pe_of)
    assert j_sm <= 1.2 * j_gm, (j_sm, j_gm)


# --- PR3: CSR round-trip, queue rewrite, compile cache ------------------------

def test_to_device_csr_roundtrip():
    """_HostGraph.to_device must produce a VALID padded CSR: exact indptr
    prefix (no clamping artifacts), sorted rows, and per-row neighbour
    multisets identical to the host arrays."""
    from repro.core.multisection import _HostGraph, host_graph_from

    g0 = G.gen_rgg(300, seed=11)
    hg = host_graph_from(g0)
    N, M = 512, 4096  # generous padding
    g = hg.to_device(N, M)
    ind = np.asarray(g.indptr)
    rows = np.asarray(g.rows)
    cols = np.asarray(g.cols)
    m = int(g.m)
    n = int(g.n)
    assert ind.shape == (N + 1,)
    assert ind[0] == 0 and ind[-1] == m
    assert (np.diff(ind) >= 0).all()
    # padding rows (>= n) are empty and all point at the tail
    assert (ind[n:] == m).all()
    # rows sorted over real slots, consistent with indptr
    assert (np.diff(rows[:m]) >= 0).all()
    for u in range(n):
        lo, hi = ind[u], ind[u + 1]
        assert (rows[lo:hi] == u).all()
        expect = np.sort(hg.cols[hg.rows == u])
        got = np.sort(cols[lo:hi])
        assert np.array_equal(got, expect), u
    # padded edge slots are weight-0 anchors
    assert (np.asarray(g.ewgt)[m:] == 0).all()
    assert (rows[m:] == N - 1).all()


def test_queue_equals_naive():
    """queue and naive pad subgraphs identically and salt by hierarchy
    position, so their mappings must be bit-equal for a fixed seed."""
    g = G.gen_rgg(800, seed=5)
    h = Hierarchy(a=(3, 4), d=(1.0, 10.0))
    a = hierarchical_multisection(g, h, eps=0.03, preset="fast", strategy="queue", seed=9)
    b = hierarchical_multisection(g, h, eps=0.03, preset="fast", strategy="naive", seed=9)
    assert np.array_equal(a.pe_of, b.pe_of)
    assert a.stats["partition_calls"] == b.stats["partition_calls"]


def test_compile_cache_reuse():
    """A repeat run must be all cache hits (no new XLA programs)."""
    g = G.gen_rgg(700, seed=6)
    h = Hierarchy(a=(4, 2), d=(1.0, 10.0))
    hierarchical_multisection(g, h, preset="fast", strategy="bucket", seed=1)
    res = hierarchical_multisection(g, h, preset="fast", strategy="bucket", seed=1)
    cc = res.stats["compile_cache"]
    assert cc["misses"] == 0 and cc["hits"] > 0, cc


# --- PR5: planner/executor split, cross-request coalescing, ell deg ----------

def test_ell_deg_pooled_mean():
    """_ell_deg_for must use the REAL pooled mean degree sum(m)/sum(n), not
    the max of per-member ceil-means (which over-padded mixed buckets)."""
    import dataclasses
    from repro.core.graph import default_ell_deg
    from repro.core.multisection import _ell_deg_for

    @dataclasses.dataclass
    class Fake:
        n: int
        m: int

    members = [Fake(n=100, m=400), Fake(n=10, m=300)]  # means 4 and 30
    # pooled: ceil(700/110) = 7, NOT max(4, 30) = 30
    assert _ell_deg_for(members, "ell") == default_ell_deg(1, 7)
    assert _ell_deg_for(members, "xla") is None


def test_bucket_equals_naive_bitwise(g):
    """Non-circular oracle for the planner path: bucket pads each subgraph
    to the SAME pow2 shapes naive uses, and vmap lanes are independent, so
    the bucket strategy (which now runs entirely on LevelPlanner +
    execute_group_batch) must reproduce the naive strategy's mapping
    bit-for-bit. A planning/batching bug shows up here even though both
    in-process bucket paths share the planner code."""
    a = hierarchical_multisection(g, H_PAPER, eps=0.03, preset="fast",
                                  strategy="bucket", seed=2)
    b = hierarchical_multisection(g, H_PAPER, eps=0.03, preset="fast",
                                  strategy="naive", seed=2)
    assert np.array_equal(a.pe_of, b.pe_of)


def test_level_planner_matches_run_loop(g):
    """Manually stepping a LevelPlanner (the mapping service's usage
    pattern) must match the one-shot driver exactly."""
    from repro.core.multisection import (LevelPlanner, execute_group_batch)

    direct = hierarchical_multisection(g, H_PAPER, eps=0.03, preset="fast",
                                       strategy="bucket", seed=2)
    planner = LevelPlanner(g, H_PAPER, eps=0.03, preset="fast", seed=2)
    while True:
        groups = planner.plan()
        if not groups:
            break
        planner.advance([execute_group_batch([gr], planner.cache_stats)[0]
                         for gr in groups])
    res = planner.result()
    assert np.array_equal(direct.pe_of, res.pe_of)
    assert direct.stats["partition_calls"] == res.stats["partition_calls"]
    assert direct.stats["padded_vertex_work"] == res.stats["padded_vertex_work"]


# --- PR7: device-resident multisection ---------------------------------------

H_SMALL = Hierarchy(a=(2, 2), d=(1.0, 10.0))


@pytest.fixture(scope="module")
def g_small():
    return G.gen_rgg(300, seed=13)


def test_split_blocks_matches_host_split():
    """graph.split_blocks (the on-device induced-subgraph op) must be
    BITWISE identical to the host `_split` extraction — every child array
    including padding slots, sizes and weights."""
    import jax.numpy as jnp
    from repro.core.multisection import _split, host_graph_from

    g0 = G.gen_rgg(400, seed=21)
    hg = host_graph_from(g0)
    rng = np.random.default_rng(0)
    k = 3
    part = rng.integers(0, k, hg.n).astype(np.int32)
    hg.depth = 2
    host_children = _split(hg, part, k, 1, 1, k)

    N, M = g0.N, g0.M
    pb = np.full(N, k, np.int32)
    pb[: hg.n] = part
    orig = jnp.asarray(
        np.concatenate([np.arange(hg.n), np.full(N - hg.n, hg.n)]).astype(np.int32))
    ch, corig, wsum = G.split_blocks(g0, jnp.asarray(pb), orig, k,
                                     jnp.int32(hg.n))
    for b, hc in enumerate(host_children):
        dev = hc.to_device(N, M)  # children keep the parent's padded shapes
        assert int(ch.n[b]) == hc.n and int(ch.m[b]) == hc.m
        assert np.array_equal(np.asarray(ch.vwgt[b]), np.asarray(dev.vwgt))
        assert np.array_equal(np.asarray(ch.rows[b]), np.asarray(dev.rows))
        assert np.array_equal(np.asarray(ch.cols[b]), np.asarray(dev.cols))
        assert np.array_equal(np.asarray(ch.ewgt[b]), np.asarray(dev.ewgt))
        assert np.array_equal(np.asarray(ch.indptr[b]), np.asarray(dev.indptr))
        co = np.asarray(corig[b])
        assert np.array_equal(co[: hc.n], hc.orig_ids)
        assert (co[hc.n:] == hg.n).all()  # pads hit the sentinel
        assert np.float32(wsum[b]) == np.float32(hc.vwgt.sum())


@pytest.mark.parametrize("preset", ["fast", "eco", "strong"])
def test_device_equals_host_reference_presets(g_small, preset):
    """The fully device-resident level loop must be bit-identical to its
    host-reference twin (resident=False under the same strategy) — the
    regression contract for the on-device split/eps/scatter pipeline."""
    a = hierarchical_multisection(g_small, H_SMALL, eps=0.03, preset=preset,
                                  strategy="device", seed=3)
    b = hierarchical_multisection(g_small, H_SMALL, eps=0.03, preset=preset,
                                  strategy="device", seed=3, resident=False)
    assert np.array_equal(a.pe_of, b.pe_of)
    assert a.stats["partition_calls"] == b.stats["partition_calls"]


@pytest.mark.parametrize("backend", ["auto", "ell", "xla"])
def test_device_equals_host_reference_backends(g_small, backend):
    a = hierarchical_multisection(g_small, H_SMALL, eps=0.03, preset="fast",
                                  strategy="device", seed=5, backend=backend)
    b = hierarchical_multisection(g_small, H_SMALL, eps=0.03, preset="fast",
                                  strategy="device", seed=5, backend=backend,
                                  resident=False)
    assert np.array_equal(a.pe_of, b.pe_of)


def test_bucket_resident_equals_host_mirror(g):
    """bucket with the device-resident level loop (the default) must equal
    the PR-5 host-mirror loop (resident=False) bit-for-bit — and therefore
    naive too (test_bucket_equals_naive_bitwise closes that triangle)."""
    a = hierarchical_multisection(g, H_PAPER, eps=0.03, preset="fast",
                                  strategy="bucket", seed=2)
    b = hierarchical_multisection(g, H_PAPER, eps=0.03, preset="fast",
                                  strategy="bucket", seed=2, resident=False)
    assert np.array_equal(a.pe_of, b.pe_of)
    assert a.stats["partition_calls"] == b.stats["partition_calls"]
    assert a.stats["resident"] and not b.stats["resident"]


def test_device_strategy_single_array_fetch(g_small):
    """The device strategy's acceptance contract: exactly ONE device->host
    array fetch per request (the final pe_of) — no bulk label or mirror
    traffic, no per-level metadata fetches either."""
    from repro.core.multisection import (reset_transfer_stats,
                                         transfer_stats)

    # warm: compiles + memoized program construction must not pollute the
    # measured counters
    hierarchical_multisection(g_small, H_SMALL, preset="fast",
                              strategy="device", seed=1)
    reset_transfer_stats()
    res = hierarchical_multisection(g_small, H_SMALL, preset="fast",
                                    strategy="device", seed=1)
    xf = transfer_stats()
    assert xf["d2h_array_fetches"] == 1, xf
    assert xf["d2h_bytes"] == res.pe_of.nbytes, xf
    # the root metadata read (n, m ints) is the only per-request meta cost
    assert xf["d2h_meta_fetches"] <= 1, xf


def test_bucket_resident_meta_only_transfers(g_small):
    """bucket-resident moves METADATA per level (child sizes/weights), one
    bulk fetch total; the PR-5 host mirror fetched full arrays per level."""
    from repro.core.multisection import (reset_transfer_stats,
                                         transfer_stats)

    hierarchical_multisection(g_small, H_SMALL, preset="fast",
                              strategy="bucket", seed=1)
    reset_transfer_stats()
    hierarchical_multisection(g_small, H_SMALL, preset="fast",
                              strategy="bucket", seed=1)
    res_xf = transfer_stats()
    reset_transfer_stats()
    hierarchical_multisection(g_small, H_SMALL, preset="fast",
                              strategy="bucket", seed=1, resident=False)
    host_xf = transfer_stats()
    assert res_xf["d2h_array_fetches"] == 1, res_xf
    assert host_xf["d2h_array_fetches"] > res_xf["d2h_array_fetches"]
    assert host_xf["d2h_bytes"] > res_xf["d2h_bytes"]


def test_i32_overflow_guard():
    """Graphs at/above 2^31 vertices or edge slots must be rejected before
    any int32 index array silently wraps."""
    from repro.core.graph import check_i32_range

    check_i32_range(2**31 - 1, 2**31 - 1)  # max representable: fine
    with pytest.raises(ValueError, match="int32"):
        check_i32_range(2**31, 8)
    with pytest.raises(ValueError, match="int32"):
        check_i32_range(8, 2**31)


def test_host_graph_dtypes_and_result_dtype(g_small):
    """The unified store is f32/i32 end-to-end: no silent f64/i64 upcasts
    in the host view, and pe_of comes back int32 from every strategy."""
    from repro.core.multisection import host_graph_from

    hg = host_graph_from(g_small)
    assert hg.vwgt.dtype == np.float32 and hg.ewgt.dtype == np.float32
    assert hg.rows.dtype == np.int32 and hg.cols.dtype == np.int32
    assert hg.orig_ids.dtype == np.int32
    for strategy in ("naive", "bucket", "device"):
        res = hierarchical_multisection(g_small, H_SMALL, preset="fast",
                                        strategy=strategy, seed=1)
        assert res.pe_of.dtype == np.int32, strategy


def test_merged_dispatch_lane_independent(g):
    """execute_group_batch over same-key groups of DIFFERENT hierarchies
    returns bit-identical per-member results vs solo dispatches — the
    invariant the mapping service's cross-request coalescing rests on."""
    from repro.core.multisection import LevelPlanner, execute_group_batch

    g2 = G.gen_rgg(2500, seed=8)
    p1 = LevelPlanner(g, H_PAPER, eps=0.03, preset="fast", seed=0)
    p2 = LevelPlanner(g2, H_PAPER, eps=0.03, preset="fast", seed=5)
    g1s, g2s = p1.plan(), p2.plan()
    assert len(g1s) == len(g2s) == 1  # one root group each
    assert g1s[0].exec_key == g2s[0].exec_key
    cs = {"hits": 0, "misses": 0}
    solo1 = execute_group_batch([g1s[0]], cs)[0]
    solo2 = execute_group_batch([g2s[0]], cs)[0]
    merged = execute_group_batch([g1s[0], g2s[0]], cs, pad_batch_pow2=True)
    assert np.array_equal(merged[0], solo1)
    assert np.array_equal(merged[1], solo2)
