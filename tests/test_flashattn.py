"""Flash-attention Pallas kernel vs oracle: shape/dtype/mask sweeps."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.flashattn import flash_attention_pallas
from repro.models.attention import _sdpa


def _qkv(bh, s, d, dt, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda: jnp.asarray(rng.standard_normal((bh, s, d)), dt)
    return mk(), mk(), mk()


@pytest.mark.parametrize("s", [128, 256, 300, 384])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_oracle(s, causal):
    q, k, v = _qkv(2, s, 64, jnp.float32, seed=s)
    a = ref.flash_ref(q, k, v, causal)
    b = flash_attention_pallas(q, k, v, causal, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


@pytest.mark.parametrize("window", [64, 128])
def test_flash_sliding_window(window):
    q, k, v = _qkv(2, 256, 64, jnp.float32, seed=window)
    a = ref.flash_ref(q, k, v, True, window)
    b = flash_attention_pallas(q, k, v, True, window, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dt):
    q, k, v = _qkv(2, 256, 128, dt, seed=7)
    a = ref.flash_ref(q, k, v, True)
    b = flash_attention_pallas(q, k, v, True, interpret=True)
    atol = 0.06 if dt == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=atol)


def test_flash_gqa_wrapper_matches_sdpa():
    """ops.flash_attention (GQA layout) vs the model's _sdpa path."""
    rng = np.random.default_rng(3)
    B, S, H, Hkv, D = 2, 128, 8, 2, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    kx = jnp.repeat(k, H // Hkv, axis=2)
    vx = jnp.repeat(v, H // Hkv, axis=2)
    mask = (jnp.arange(S)[None, :] <= jnp.arange(S)[:, None])[None]
    a = _sdpa(q, kx, vx, mask)
    b = ops.flash_attention(q, k, v, causal=True, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


def test_flash_in_model_path():
    """self_attention(ctx.use_flash) == dense-mask path (smoke arch)."""
    from repro.configs.registry import get_smoke_config
    from repro.models import attention as A
    from repro.models.sharding import ShardCtx
    import dataclasses

    cfg = get_smoke_config("llama3.2-3b")
    key = jax.random.PRNGKey(0)
    p = A.attn_params(cfg, key)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 32, cfg.d_model)) * 0.1,
                    jnp.float32)

    class _Ctx:  # minimal stand-in (mesh-free)
        use_flash = True
        attn_seq_shard = False

    a, _ = A.self_attention(cfg, p, x, causal=True)
    b, _ = A.self_attention(cfg, p, x, causal=True, ctx=_Ctx())
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3)
