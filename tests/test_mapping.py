"""Mapping phase: J evaluation, greedy construction, swap refinement."""
import itertools

import numpy as np
import jax.numpy as jnp
import pytest
from hypcompat import given, settings, st

from repro.core import graph as G
from repro.core.hierarchy import Hierarchy
from repro.core.mapping import (evaluate_J, greedy_mapping, map_cost_dense,
                                quotient_matrix, swap_refine)


def _brute_force(C, D):
    k = C.shape[0]
    best, best_pi = np.inf, None
    for pi in itertools.permutations(range(k)):
        pi = np.asarray(pi)
        c = map_cost_dense(C, D, pi)
        if c < best:
            best, best_pi = c, pi
    return best, best_pi


@given(st.integers(0, 500))
@settings(max_examples=15, deadline=None)
def test_greedy_plus_swaps_near_optimal_small(seed):
    """On k=6 instances, greedy+swaps lands within 1.3x of the exact QAP
    optimum (brute force)."""
    h = Hierarchy(a=(3, 2), d=(1.0, 10.0))
    k = h.k
    rng = np.random.default_rng(seed)
    C = rng.random((k, k)) * (rng.random((k, k)) < 0.5)
    C = np.triu(C, 1)
    C = C + C.T
    D = h.distance_table()
    opt, _ = _brute_force(C, D)
    pi = swap_refine(C, h, greedy_mapping(C, h), seed=seed)
    got = map_cost_dense(C, D, pi)
    assert sorted(pi.tolist()) == list(range(k))  # a bijection
    assert got <= 1.3 * opt + 1e-9, (got, opt)


def test_swap_refine_never_worsens():
    h = Hierarchy(a=(4, 4), d=(1.0, 7.0))
    rng = np.random.default_rng(1)
    k = h.k
    C = rng.random((k, k))
    C = np.triu(C, 1); C = C + C.T
    D = h.distance_table()
    pi0 = np.arange(k)
    before = map_cost_dense(C, D, pi0)
    pi1 = swap_refine(C, h, pi0, seed=2)
    assert map_cost_dense(C, D, pi1) <= before + 1e-9


def test_evaluate_J_matches_dense():
    g = G.gen_rgg(400, seed=9)
    h = Hierarchy(a=(2, 2, 2), d=(1.0, 5.0, 25.0))
    rng = np.random.default_rng(0)
    n = int(g.n)
    part = rng.integers(0, h.k, n)
    # dense path: sum over undirected edges
    rows = np.asarray(g.rows)[: int(g.m)]
    cols = np.asarray(g.cols)[: int(g.m)]
    w = np.asarray(g.ewgt)[: int(g.m)]
    D = h.distance_table()
    expect = float((w * D[part[rows], part[cols]]).sum() / 2.0)
    assert abs(evaluate_J(g, h, part) - expect) < 1e-3 * max(expect, 1)


def test_quotient_matrix_symmetry_and_mass():
    g = G.gen_grid(10)
    n = int(g.n)
    part = (np.arange(n) * 4) // n
    C = quotient_matrix(g, part, 4)
    assert np.allclose(C, C.T)
    assert np.allclose(np.diag(C), 0.0)
    # total cross mass equals the edge cut
    cut = float(G.edge_cut(g, jnp.asarray(np.pad(part, (0, g.N - n)), jnp.int32)))
    assert abs(C.sum() / 2.0 - cut) < 1e-3


def test_evaluate_J_rejects_oversized_pe_of():
    """Regression: a pe_of longer than the padded graph used to die with a
    confusing negative-dimension error from jnp.zeros; now a clear
    ValueError."""
    g = G.gen_grid(6)
    h = Hierarchy(a=(2, 2), d=(1.0, 10.0))
    bad = np.zeros(g.N + 5, np.int64)
    with pytest.raises(ValueError, match="pe_of"):
        evaluate_J(g, h, bad)
    # shorter-than-N (real-size) assignments still work
    part = np.zeros(int(g.n), np.int64)
    assert evaluate_J(g, h, part) == 0.0
