"""Overload, deadlines, fault containment, and degradation for the service.

Shares graph shapes with test_serve_mapper.py so full-suite runs reuse the
same compiled executables.
"""
import json
import time

import jax
import numpy as np
import pytest

from repro.core import graph as G
from repro.core.api import SharedMapConfig, shared_map_direct
from repro.core.baselines import greedy_baseline
from repro.core.hierarchy import Hierarchy
from repro.core.mapping import evaluate_J
from repro.core.multisection import clear_compile_cache
from repro.faults import FaultInjector, InjectedFault
from repro.serve.admission import (ADMIT, ADMIT_DEGRADED, PREEMPT, SHED,
                                   AdmissionController, DeadlineExceededError,
                                   RetryPolicy, ServiceClosedError,
                                   ServiceOverloadError)
from repro.serve.mapper import MappingService, validate_request
from repro.serve.tracker import InMemoryTracker, JsonlTracker, Tracker

H = Hierarchy(a=(4, 2), d=(1.0, 10.0))
CFG = SharedMapConfig(preset="fast")


@pytest.fixture(scope="module")
def graphs():
    return [G.gen_rgg(300, seed=40 + i) for i in range(4)]


# ---------------------------------------------------------------- admission


def test_admission_controller_decide_matrix():
    adm = AdmissionController(max_inflight=2, max_queue=4, degrade_at=0.5)
    assert adm.decide(0, None, degrade_ok=False) == ADMIT
    adm.queued = 2  # at the soft watermark (0.5 * 4)
    assert adm.decide(0, None, degrade_ok=True) == ADMIT_DEGRADED
    assert adm.decide(0, None, degrade_ok=False) == ADMIT
    adm.queued = 4  # at the hard bound
    assert adm.decide(0, None, degrade_ok=False) == SHED  # nobody to evict
    assert adm.decide(1, 0, degrade_ok=False) == PREEMPT  # strictly higher
    assert adm.decide(0, 0, degrade_ok=False) == SHED     # ties never evict
    assert adm.decide(0, 1, degrade_ok=False) == SHED


def test_admission_controller_bounds_and_capacity():
    adm = AdmissionController(max_inflight=1, max_queue=1, degrade_at=0.75)
    assert adm.hard_bound() == 1
    assert adm.soft_bound() == 0  # clamped inside [0, hard)
    assert adm.has_capacity()
    adm.note_start()
    assert not adm.has_capacity()
    adm.note_done()
    adm.note_queued()
    adm.note_shed()
    adm.note_shed(preempted=True)
    adm.note_deadline_miss()
    snap = adm.snapshot()
    assert snap["admitted"] == 1 and snap["shed"] == 1
    assert snap["preempted"] == 1 and snap["deadline_miss"] == 1
    zero = AdmissionController(max_queue=0)
    assert zero.decide(0, None, degrade_ok=False) == SHED


def test_retry_policy_backoff_and_transience():
    rp = RetryPolicy(max_retries=3, backoff_base_s=0.01, backoff_factor=2.0)
    assert rp.backoff_s(0) == pytest.approx(0.01)
    assert rp.backoff_s(2) == pytest.approx(0.04)
    assert rp.is_transient(InjectedFault("x", transient=True))
    assert not rp.is_transient(InjectedFault("x", transient=False))
    assert rp.is_transient(MemoryError())
    assert rp.is_transient(RuntimeError("RESOURCE_EXHAUSTED: out of HBM"))
    assert not rp.is_transient(ValueError("malformed"))


# ----------------------------------------------------------------- overload


def test_burst_shed_and_admitted_bit_identical(graphs):
    """Closed-loop burst over the bounds: overflow gets a typed
    ServiceOverloadError, admitted requests complete bit-identical to the
    direct path."""
    tr = InMemoryTracker()
    svc = MappingService(max_inflight=1, max_queue=2, tracker=tr)
    try:
        # submit_many holds the scheduler lock across the whole burst, so
        # the admission decisions are deterministic: 2 queued, 4 shed.
        futs = svc.submit_many(
            [(graphs[i % 4], H, SharedMapConfig(preset="fast", seed=i))
             for i in range(6)])
        shed = [f for f in futs if isinstance(f.exception(timeout=600),
                                              ServiceOverloadError)]
        done = [f for f in futs if f.exception(timeout=600) is None]
        assert len(shed) == 4 and len(done) == 2
        assert shed[0] is futs[2]  # FIFO admission: first two got in
        exc = futs[2].exception()
        assert exc.queued == 2 and exc.retry_after_s > 0
        for i in (0, 1):
            d = shared_map_direct(graphs[i], H,
                                  SharedMapConfig(preset="fast", seed=i))
            r = futs[i].result()
            assert np.array_equal(d.pe_of, r.pe_of) and d.J == r.J
            assert r.stats["degradation"]["level"] == 0
        snap = svc.stats()["admission"]
        assert snap["admitted"] == 2 and snap["shed"] == 4
        assert tr.counters["service.shed"] == 4
        assert tr.counters["service.admitted"] == 2
    finally:
        svc.close()


def test_priority_preempts_lowest_waiter(graphs):
    svc = MappingService(max_queue=1)
    try:
        with svc._cv:  # freeze the scheduler: decisions are deterministic
            f_low = svc.submit(graphs[0], H, CFG, priority=0)
            f_high = svc.submit(graphs[1], H, CFG, priority=5)
        exc = f_low.exception(timeout=600)
        assert isinstance(exc, ServiceOverloadError)
        assert "preempted" in str(exc)
        d = shared_map_direct(graphs[1], H, CFG)
        r = f_high.result(timeout=600)
        assert np.array_equal(d.pe_of, r.pe_of)
        assert svc.stats()["admission"]["preempted"] == 1
    finally:
        svc.close()


def test_priority_orders_execution(graphs):
    order = []
    svc = MappingService(max_inflight=1, batch_window_s=0.0)
    try:
        with svc._cv:
            for gi, pri in ((0, 0), (1, 5), (2, 1)):
                fut = svc.submit(graphs[gi], H, CFG, priority=pri)
                fut.add_done_callback(lambda f, gi=gi: order.append(gi))
            assert len(svc._queue) == 3
        svc.close(wait=True)  # drain: all three resolve before return
        assert order == [1, 2, 0]  # high priority first, FIFO below
    finally:
        svc.close()


# ----------------------------------------------------------------- deadlines


def test_deadline_expired_at_submit(graphs):
    svc = MappingService()
    try:
        fut = svc.submit(graphs[0], H, SharedMapConfig(preset="fast", seed=99),
                         deadline_s=0.0)
        assert isinstance(fut.exception(timeout=5), DeadlineExceededError)
        assert svc.stats()["admission"]["deadline_miss"] == 1
    finally:
        svc.close()


def test_deadline_expires_in_queue(graphs):
    import time
    svc = MappingService()
    try:
        with svc._cv:  # hold the scheduler so the request stays queued
            fut = svc.submit(graphs[0], H,
                             SharedMapConfig(preset="fast", seed=98),
                             deadline_s=0.01)
            time.sleep(0.05)  # deadline passes while queued
        # the sweep runs before any admission, so this is deterministic
        assert isinstance(fut.exception(timeout=10), DeadlineExceededError)
        # the service keeps serving afterwards
        r = svc.map(graphs[0], H, CFG)
        assert np.array_equal(r.pe_of, shared_map_direct(graphs[0], H, CFG).pe_of)
    finally:
        svc.close()


def test_deadline_cancels_mid_pipeline(graphs):
    """A short deadline on a cold (compile-bound) request is enforced at
    the cooperative between-level checkpoints."""
    clear_compile_cache()
    jax.clear_caches()  # guarantee the first dispatch compiles (seconds)
    svc = MappingService()
    try:
        fut = svc.submit(graphs[2], H, CFG, deadline_s=0.2)
        assert isinstance(fut.exception(timeout=600), DeadlineExceededError)
        # scheduler thread survived; the same request now completes
        r = svc.map(graphs[2], H, CFG)
        assert np.array_equal(r.pe_of, shared_map_direct(graphs[2], H, CFG).pe_of)
    finally:
        svc.close()


def test_checkpoint_aborts_between_levels(graphs):
    """The checkpoint hook threads through the direct path too, firing
    between multisection levels."""
    calls = []
    shared_map_direct(graphs[0], H, CFG, checkpoint=lambda: calls.append(1))
    assert len(calls) >= 2  # once per level at least

    class Abort(Exception):
        pass

    seen = []

    def ck():
        seen.append(1)
        if len(seen) == 2:
            raise Abort()

    with pytest.raises(Abort):
        shared_map_direct(graphs[0], H, CFG, checkpoint=ck)
    assert len(seen) == 2  # aborted at the second level boundary


# ------------------------------------------------------- faults / containment


def test_transient_dispatch_fault_retried_bit_identical(graphs):
    """A transient fault in a merged dispatch isolates and retries; the
    caller still gets the full-quality, bit-identical result."""
    inj = FaultInjector(fail_at={"dispatch": (0, 1)})
    svc = MappingService(fault_injector=inj,
                         retry=RetryPolicy(backoff_base_s=0.001))
    try:
        r = svc.map(graphs[0], H, CFG)
        d = shared_map_direct(graphs[0], H, CFG)
        assert np.array_equal(d.pe_of, r.pe_of) and d.J == r.J
        assert r.stats["degradation"]["level"] == 0
        flt = svc.stats()["faults"]
        assert flt["dispatch_failures"] >= 1
        assert flt["isolated"] >= 1
        assert flt["retries"] >= 1
        assert inj.fired == [("dispatch", 0), ("dispatch", 1)]
    finally:
        svc.close()


def test_persistent_transient_failure_degrades_to_greedy(graphs):
    """Retries exhausted on an always-failing dispatch seam: the request
    degrades to the greedy floor instead of failing (degrade_on_failure)."""
    inj = FaultInjector(rates={"dispatch": 1.0})
    svc = MappingService(fault_injector=inj,
                         retry=RetryPolicy(max_retries=1, backoff_base_s=0.001))
    try:
        r = svc.map(graphs[0], H, CFG)
        deg = r.stats["degradation"]
        assert deg["level"] == 3 and deg["mode"] == "greedy"
        expect = greedy_baseline(graphs[0], H, seed=CFG.seed)
        assert np.array_equal(r.pe_of, expect)
        assert r.J == evaluate_J(graphs[0], H, expect)
        flt = svc.stats()["faults"]
        assert flt["contained"] >= 1 and flt["degraded"] >= 1
    finally:
        svc.close()


def test_failure_degrades_to_fast_preset_rung(graphs):
    """An eco request whose full pipeline fails falls to the fast-preset
    rung — a REAL multisection result, bit-identical to a direct fast run —
    and the degraded answer is never cached under the original request."""
    inj = FaultInjector(fail_at={"dispatch": (0, 1)})
    cfg_eco = SharedMapConfig(preset="eco")
    svc = MappingService(fault_injector=inj,
                         retry=RetryPolicy(max_retries=0, backoff_base_s=0.001))
    try:
        r = svc.map(graphs[1], H, cfg_eco)
        deg = r.stats["degradation"]
        assert deg["level"] == 2 and deg["mode"] == "fast_preset"
        d_fast = shared_map_direct(graphs[1], H,
                                   SharedMapConfig(preset="fast"))
        assert np.array_equal(r.pe_of, d_fast.pe_of)
        # degraded result was NOT cached: the retry (injector exhausted)
        # recomputes at full quality
        again = svc.map(graphs[1], H, cfg_eco)
        assert again.stats["result_cache"]["hit"] is False
        assert again.stats["degradation"]["level"] == 0
        d_eco = shared_map_direct(graphs[1], H, cfg_eco)
        assert np.array_equal(again.pe_of, d_eco.pe_of)
    finally:
        svc.close()


def test_nontransient_failure_propagates(graphs):
    inj = FaultInjector(rates={"dispatch": 1.0}, transient=False)
    svc = MappingService(fault_injector=inj)
    try:
        with pytest.raises(InjectedFault):
            svc.map(graphs[0], H, SharedMapConfig(preset="fast", seed=11))
        assert svc._thread.is_alive()  # containment: scheduler survived
    finally:
        svc.close()


def test_degrade_on_failure_disabled_propagates(graphs):
    inj = FaultInjector(rates={"dispatch": 1.0})
    svc = MappingService(fault_injector=inj, degrade_on_failure=False,
                         retry=RetryPolicy(max_retries=0))
    try:
        with pytest.raises(InjectedFault):
            svc.map(graphs[0], H, SharedMapConfig(preset="fast", seed=12))
    finally:
        svc.close()


def test_finalize_fault_degrades(graphs):
    inj = FaultInjector(fail_at={"finalize": (0,)})
    svc = MappingService(fault_injector=inj)
    try:
        r = svc.map(graphs[3], H, CFG)
        assert r.stats["degradation"]["level"] > 0  # served, degraded
    finally:
        svc.close()


def test_cache_fault_contained(graphs):
    """Injected faults at the cache seam degrade to cache misses; the
    request still resolves at full quality."""
    inj = FaultInjector(fail_at={"cache": (0, 1)})
    svc = MappingService(fault_injector=inj)
    try:
        r = svc.map(graphs[0], H, CFG)
        d = shared_map_direct(graphs[0], H, CFG)
        assert np.array_equal(d.pe_of, r.pe_of)
        assert r.stats["degradation"]["level"] == 0
        assert svc.stats()["faults"]["cache_faults"] == 2
        # the put was skipped -> same request recomputes (then caches)
        again = svc.map(graphs[0], H, CFG)
        assert again.stats["result_cache"]["hit"] is False
        third = svc.map(graphs[0], H, CFG)
        assert third.stats["result_cache"]["hit"] is True
    finally:
        svc.close()


# ----------------------------------------------------- overload degradation


def test_degrade_on_overload_inline_ladder(graphs):
    """Under hard overload with degradation enabled, requests are answered
    inline: cached-nearby when the graph was seen before, greedy otherwise."""
    svc = MappingService(degrade_on_overload=True)
    try:
        primed = svc.map(graphs[0], H, CFG)  # populate the nearby index
        svc.admission.max_queue = 0  # force hard overload
        near = svc.map(graphs[0], H, SharedMapConfig(preset="eco", seed=7))
        assert near.stats["degradation"]["mode"] == "cached_nearby"
        assert near.stats["degradation"]["level"] == 1
        assert np.array_equal(near.pe_of, primed.pe_of)
        cold = svc.map(graphs[1], H, CFG)
        assert cold.stats["degradation"]["mode"] == "greedy"
        assert np.array_equal(cold.pe_of,
                              greedy_baseline(graphs[1], H, seed=CFG.seed))
        assert svc.stats()["admission"]["degraded"] == 2
    finally:
        svc.close()


# ------------------------------------------------------ validation boundary


def test_validation_rejects_malformed_inputs(graphs):
    import jax.numpy as jnp
    g = graphs[0]
    svc = MappingService()
    try:
        with pytest.raises(ValueError, match="empty graph"):
            svc.submit(g._replace(n=jnp.asarray(0, g.n.dtype)), H, CFG)
        small = G.gen_rgg(6, seed=1)
        with pytest.raises(ValueError, match="k=8"):
            svc.submit(small, H, CFG)  # k > n
        with pytest.raises(ValueError, match="eps"):
            svc.submit(g, H, SharedMapConfig(eps=0.0))
        with pytest.raises(ValueError, match="strategy"):
            svc.submit(g, H, SharedMapConfig(strategy="quantum"))
        with pytest.raises(ValueError, match="preset"):
            svc.submit(g, H, SharedMapConfig(preset="turbo"))
        bad_cols = np.asarray(g.cols).copy()
        bad_cols[0] = 10 ** 6
        with pytest.raises(ValueError, match="out of range"):
            svc.submit(g._replace(cols=jnp.asarray(bad_cols)), H, CFG)
    finally:
        svc.close()


def test_validate_request_direct():
    small = G.gen_rgg(6, seed=1)
    with pytest.raises(ValueError):
        validate_request(small, H, CFG)
    validate_request(G.gen_rgg(64, seed=1), H, CFG)  # clean passes


def test_submit_many_mixed_batch_isolated(graphs):
    """One malformed request in a coalesced batch fails only its own
    Future; siblings complete bit-identical to the direct path."""
    svc = MappingService()
    try:
        small = G.gen_rgg(6, seed=1)  # k > n: fails validation
        futs = svc.submit_many([(graphs[0], H, CFG), (small, H, CFG),
                                (graphs[1], H, CFG)])
        assert isinstance(futs[1].exception(timeout=600), ValueError)
        for i, gi in ((0, 0), (2, 1)):
            d = shared_map_direct(graphs[gi], H, CFG)
            assert np.array_equal(d.pe_of, futs[i].result(timeout=600).pe_of)
    finally:
        svc.close()


def test_corrupt_graph_isolated_without_validation(graphs):
    """With boundary validation off, a corrupt graph fails deep in the
    pipeline — but only ITS request; coalesced siblings and the scheduler
    thread survive. The corruption is a truncated adjacency (wrong-shaped
    cols): shape mismatches throw on every backend, unlike out-of-range
    indices, which device gathers clamp silently since the split moved
    on-device (that case is what validate=True rejects at the boundary)."""
    import jax.numpy as jnp
    corrupt = graphs[0]._replace(
        cols=jnp.asarray(np.asarray(graphs[0].cols)[:3]))
    svc = MappingService(validate=False)
    try:
        futs = svc.submit_many([(graphs[2], H, CFG), (corrupt, H, CFG),
                                (graphs[3], H, CFG)])
        exc = futs[1].exception(timeout=600)
        assert exc is not None and not isinstance(exc, ServiceOverloadError)
        for i, gi in ((0, 2), (2, 3)):
            d = shared_map_direct(graphs[gi], H, CFG)
            assert np.array_equal(d.pe_of, futs[i].result(timeout=600).pe_of)
        assert svc._thread.is_alive()
    finally:
        svc.close()


# ------------------------------------------------------------------ shutdown


def test_close_nowait_fails_pending_futures(graphs):
    """close(wait=False) must FAIL (not leak) every pending Future, even
    with a compile-bound request in flight."""
    import time
    clear_compile_cache()
    jax.clear_caches()  # the in-flight dispatch will take seconds
    svc = MappingService()
    fut = svc.submit(graphs[1], H, CFG)
    time.sleep(0.05)  # let the scheduler pick it up
    t0 = time.time()
    svc.close(wait=False)
    assert time.time() - t0 < 5.0  # prompt, not drain
    assert isinstance(fut.exception(timeout=0.1), ServiceClosedError)
    with pytest.raises(ServiceClosedError):
        svc.submit(graphs[0], H, CFG)


def test_context_manager_exits_deterministically(graphs):
    # clean exit drains: the future resolves with its result
    with MappingService() as svc:
        fut = svc.submit(graphs[0], H, CFG)
    assert fut.result(timeout=1) is not None

    # exception exit aborts: pending futures fail promptly
    class Boom(Exception):
        pass

    with pytest.raises(Boom):
        with MappingService() as svc2:
            with svc2._cv:  # keep it queued so it is provably pending
                fut2 = svc2.submit(graphs[1], H,
                                   SharedMapConfig(preset="fast", seed=77))
                raise Boom()
    assert isinstance(fut2.exception(timeout=5), ServiceClosedError)


# ---------------------------------------------------------------- trackers


def test_jsonl_tracker_records_service_history(tmp_path, graphs):
    path = str(tmp_path / "svc.jsonl")
    tr = JsonlTracker(path)
    svc = MappingService(tracker=tr)
    try:
        svc.map(graphs[0], H, SharedMapConfig(preset="fast", seed=21))
        svc.map(graphs[0], H, SharedMapConfig(preset="fast", seed=21))
    finally:
        svc.close()
        tr.close()
    recs = [json.loads(line) for line in open(path)]
    names = [r["name"] for r in recs]
    assert "service.admitted" in names
    assert "service.cache.hit" in names and "service.cache.miss" in names
    assert all("t" in r and r["kind"] in ("count", "event") for r in recs)
    with pytest.raises(ValueError):
        tr.count("after.close")


def test_counter_tracker_aggregates_service_counters(graphs):
    from repro.serve.tracker import CounterTracker
    tr = CounterTracker()
    svc = MappingService(tracker=tr)
    try:
        svc.map(graphs[0], H, SharedMapConfig(preset="fast", seed=22))
        svc.map(graphs[0], H, SharedMapConfig(preset="fast", seed=22))
        snap = svc.stats()
    finally:
        svc.close()
    # service telemetry flows into the aggregated snapshot...
    counters = snap["tracker"]["counters"]
    assert counters["service.admitted"] == 1
    assert counters["service.cache.miss"] == 1
    assert counters["service.cache.hit"] == 1
    # ...and stats() publishes level-style gauges through the sink
    gauges = snap["tracker"]["gauges"]
    assert gauges["service.queue_depth"] == 0
    assert gauges["service.cache_entries"] == 1


def test_counter_tracker_semantics_and_textfile(tmp_path):
    from repro.serve.tracker import CounterTracker
    tr = CounterTracker()
    tr.count("reqs", 2, route="a")
    tr.count("reqs", 3, route="a")
    tr.count("reqs", route="b")
    tr.gauge("depth", 7)
    tr.gauge("depth", 4)          # gauges keep the LAST value
    tr.event("shed", queued=9, reason="full", ok=True)  # str/bool skipped
    snap = tr.snapshot()
    assert snap["counters"]["reqs{route=a}"] == 5
    assert snap["counters"]["reqs{route=b}"] == 1
    assert snap["counters"]["events_total{name=shed}"] == 1
    assert snap["gauges"]["depth"] == 4
    assert snap["gauges"]["event.shed.queued"] == 9
    assert "event.shed.reason" not in snap["gauges"]
    txt = tr.to_textfile()
    assert "# TYPE reqs counter" in txt
    assert 'reqs{route="a"} 5' in txt
    assert "# TYPE depth gauge" in txt and "\ndepth 4" in txt
    # dots sanitize to Prometheus-legal names
    assert "event_shed_queued 9" in txt
    path = tmp_path / "metrics.prom"
    tr.write_textfile(str(path))
    assert path.read_text() == txt
    assert not list(tmp_path.glob("*.tmp.*"))  # atomic publish, no litter


def test_raising_tracker_never_breaks_serving(graphs):
    class BadSink(Tracker):
        def count(self, name, value=1, **tags):
            raise RuntimeError("sink down")

        def event(self, name, **fields):
            raise RuntimeError("sink down")

    svc = MappingService(tracker=BadSink(), max_inflight=1, max_queue=1)
    try:
        r = svc.map(graphs[0], H, CFG)
        d = shared_map_direct(graphs[0], H, CFG)
        assert np.array_equal(d.pe_of, r.pe_of)
    finally:
        svc.close()


# ------------------------------------------------------------ stress sweep


def test_every_future_resolves_under_fault_and_overload(graphs):
    """Acceptance: injected failures + overload; every accepted Future
    resolves with a result or a typed error and the scheduler survives."""
    inj = FaultInjector(seed=3, rates={"dispatch": 0.3})
    svc = MappingService(max_inflight=2, max_queue=4,
                         fault_injector=inj,
                         retry=RetryPolicy(max_retries=1, backoff_base_s=0.001))
    try:
        futs = []
        for wave in range(4):
            futs += svc.submit_many(
                [(graphs[i % 4], H,
                  SharedMapConfig(preset="fast", seed=100 + wave * 5 + i))
                 for i in range(5)])
        outcomes = {"ok": 0, "shed": 0}
        for f in futs:
            exc = f.exception(timeout=600)
            if exc is None:
                r = f.result()
                assert r.stats["degradation"]["level"] in (0, 1, 2, 3)
                outcomes["ok"] += 1
            else:
                assert isinstance(exc, ServiceOverloadError), exc
                outcomes["shed"] += 1
        assert outcomes["ok"] + outcomes["shed"] == 20
        assert outcomes["ok"] > 0
        assert svc._thread is None or svc._thread.is_alive()
    finally:
        svc.close()


# ------------------------------------------- PR 8 satellites: retry deadlines


def test_retry_backoff_never_overruns_deadline(graphs):
    """Regression: RetryPolicy backoff sleeps used to run their full
    exponential length regardless of the request deadline, so a retrying
    request could resolve LATE. Now each sleep is capped at the remaining
    budget and the deadline is re-checked before any re-dispatch: under a
    tight deadline the outcome is DeadlineExceededError, never a late
    success."""
    inj = FaultInjector(fail_at={"dispatch": tuple(range(50))})
    svc = MappingService(
        fault_injector=inj, degrade_on_failure=False,
        retry=RetryPolicy(max_retries=5, backoff_base_s=0.5))
    try:
        t0 = time.monotonic()
        fut = svc.submit(graphs[0], H, CFG, deadline_s=0.2)
        exc = fut.exception(timeout=120)
        elapsed = time.monotonic() - t0
        assert isinstance(exc, DeadlineExceededError), exc
        # without the cap, 5 retries sleep 0.5+1+2+4+8 = 15.5s; with it the
        # request dies within its ~0.2s budget (generous slack for jit).
        assert elapsed < 5.0, f"late failure after {elapsed:.2f}s"
    finally:
        svc.close()


def test_retry_policy_backoff_capped_by_deadline():
    pol = RetryPolicy(max_retries=3, backoff_base_s=10.0)
    assert pol.backoff_s(0) == 10.0                       # uncapped
    near = time.monotonic() + 0.05
    assert pol.backoff_s(0, deadline=near) <= 0.05        # capped at budget
    assert pol.backoff_s(0, deadline=time.monotonic() - 1) == 0.0  # expired


def test_retry_policy_transient_attribute_generic():
    """Any exception carrying ``transient`` classifies itself — the seam
    the supervisor's WorkerCrashError rides through without imports."""
    pol = RetryPolicy()

    class Crash(RuntimeError):
        transient = True

    class Fatal(RuntimeError):
        transient = False

    assert pol.is_transient(Crash("worker died"))
    assert not pol.is_transient(Fatal("bad graph"))
    assert pol.is_transient(InjectedFault("x", transient=True))
    assert not pol.is_transient(InjectedFault("x", transient=False))
    assert not pol.is_transient(ValueError("deterministic"))


# --------------------------------------- PR 8 satellites: crash-safe tracker


def test_jsonl_tracker_line_buffered_writes(tmp_path):
    """Events must reach the OS at each newline — NOT at close — so a
    crash-killed process loses at most the final partial line."""
    path = str(tmp_path / "events.jsonl")
    tr = JsonlTracker(path)
    tr.event("shed", reason="queue_full")
    tr.count("service.retry")
    # read back through a SEPARATE handle without flushing or closing
    lines = open(path).read().splitlines()
    assert len(lines) == 2
    assert json.loads(lines[0])["name"] == "shed"
    assert json.loads(lines[1])["name"] == "service.retry"
    tr.close()


def test_jsonl_tracker_atexit_ordering():
    """The tracker module's atexit flush must be registered BEFORE the
    mapper module's teardown hook (atexit is LIFO: registered-first runs
    LAST), so final events emitted during service teardown get flushed."""
    from repro.serve import mapper as mapper_mod
    from repro.serve import tracker as tracker_mod

    # the ordering is a consequence of mapper importing tracker before
    # registering its own hook (module singletons make that stable).
    assert hasattr(tracker_mod, "_flush_live_trackers")
    assert hasattr(mapper_mod, "_close_live_services")
    # functional check: a service left open at interpreter exit, with an
    # unflushed tracker, still lands its events on disk.
    import subprocess
    import sys
    code = (
        "import sys; sys.path.insert(0, 'src')\n"
        "from repro.serve.tracker import JsonlTracker\n"
        "from repro.serve.mapper import MappingService\n"
        "tr = JsonlTracker(sys.argv[1])\n"
        "svc = MappingService(tracker=tr)\n"
        "tr.event('sentinel', n=1)\n"
        "# neither close() nor flush(): atexit must do both, in order\n"
    )
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        path = f"{d}/exit.jsonl"
        subprocess.run([sys.executable, "-c", code, path], check=True,
                       cwd="/root/repo", timeout=300)
        lines = [json.loads(x) for x in open(path).read().splitlines()]
    assert any(e.get("name") == "sentinel" for e in lines)


def test_jsonl_tracker_closed_twice_is_safe(tmp_path):
    tr = JsonlTracker(str(tmp_path / "e.jsonl"))
    tr.count("x")
    tr.close()
    tr.close()
    with pytest.raises(ValueError):
        tr.count("y")
