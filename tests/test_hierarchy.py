"""Hierarchy: O(1) bit-label distances + adaptive imbalance (Lemma 5.1)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypcompat import given, settings, st

from repro.core.hierarchy import (Hierarchy, adaptive_epsilon, parse_hierarchy,
                                  pe_distance, tpu_v5e_hierarchy)

hier_st = st.lists(st.integers(2, 5), min_size=1, max_size=4).map(
    lambda a: Hierarchy(a=tuple(a), d=tuple(float(10 ** i) for i in range(len(a)))))


@given(hier_st)
@settings(max_examples=25, deadline=None)
def test_pe_distance_matches_table(h):
    k = h.k
    xs, ys = np.meshgrid(np.arange(k), np.arange(k), indexing="ij")
    vec = np.asarray(pe_distance(h, jnp.asarray(xs), jnp.asarray(ys)))
    assert np.allclose(vec, h.distance_table())


@given(hier_st)
@settings(max_examples=25, deadline=None)
def test_distance_axioms(h):
    D = h.distance_table()
    assert np.allclose(D, D.T)                       # symmetric
    assert np.allclose(np.diag(D), 0.0)              # identity
    off = D[~np.eye(h.k, dtype=bool)]
    if off.size:
        assert (off > 0).all()                       # distinct PEs communicate


def test_paper_example_distances():
    # Fig 1: H = 4:2:3, D = 1:10:100
    h = parse_hierarchy("4:2:3", "1:10:100")
    assert h.k == 24
    D = h.distance_table()
    assert D[0, 1] == 1.0       # same processor
    assert D[0, 4] == 10.0      # same node, different processor
    assert D[0, 8] == 100.0     # different node


def test_paper_example_adaptive_eps():
    """§5 worked example: 800 vertices, H=4:2, eps=0.1."""
    e_top = adaptive_epsilon(0.1, 800, 800, 8, 8, 2)
    assert abs(e_top - (1.1 ** 0.5 - 1)) < 1e-12
    sub_w = (1 + e_top) * 800 / 2
    e_sub = adaptive_epsilon(0.1, 800, sub_w, 8, 4, 1)
    assert (1 + e_sub) * sub_w / 4 <= 1.1 * 800 / 8 + 1e-9  # == L_max


@given(st.floats(0.0, 0.5), st.integers(1, 4),
       st.lists(st.integers(2, 4), min_size=1, max_size=4))
@settings(max_examples=60, deadline=None)
def test_adaptive_eps_worst_case_bounded(eps, wfac, a):
    """Lemma 5.1: even if every level maxes out its allowance, the final
    block weight stays <= (1+eps) * c(V)/k."""
    h = Hierarchy(a=tuple(a), d=(1.0,) * len(a))
    k = h.k
    total = 1000.0 * wfac
    Lmax = (1 + eps) * total / k
    w = total
    for d in range(len(a), 0, -1):
        k_sub = int(np.prod(a[:d]))
        e = adaptive_epsilon(eps, total, w, k, k_sub, d)
        w = (1 + e) * w / a[d - 1]  # worst case: one block takes the max
    assert w <= Lmax * (1 + 1e-9)


def test_v5e_hierarchies():
    assert tpu_v5e_hierarchy(False).k == 256
    assert tpu_v5e_hierarchy(True).k == 512
