"""benchmarks/compare.py: bench-telemetry diffing and the cold-path gate."""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.compare import compare, main, numeric_leaves  # noqa: E402


def _bench(cold, warm, extra=None):
    sec = {"coarsen_kernels": {"grid10000": {
        "cascade_cold_s": cold, "cascade_s": warm, "n": 10_000,
        "per_level": [{"n": 5500, "shrink": 1.82}],
    }}}
    if extra:
        sec["coarsen_kernels"]["grid10000"].update(extra)
    return {"sections": sec}


def test_numeric_leaves_walks_nested_lists_and_skips_bools():
    tree = {"a": 1, "b": [{"c": 2.5}], "d": True, "e": "str"}
    leaves = dict(numeric_leaves(tree))
    assert leaves == {"a": 1.0, "b[0].c": 2.5}


def test_self_diff_is_clean():
    b = _bench(10.0, 1.0)
    rows, regressions = compare(b, b, threshold=0.2)
    assert rows and not regressions
    assert all(delta == 0.0 for _, _, _, delta, _ in rows)


def test_cold_regression_over_threshold_flagged():
    old, new = _bench(10.0, 1.0), _bench(13.0, 1.0)  # cold +30%
    _, regressions = compare(old, new, threshold=0.2)
    assert len(regressions) == 1
    path, ov, nv, delta = regressions[0]
    assert "cascade_cold_s" in path
    assert delta == pytest.approx(0.3)


def test_warm_regression_not_gated():
    # warm +300% is informational only; the gate watches cold-path leaves
    old, new = _bench(10.0, 1.0), _bench(10.0, 4.0)
    _, regressions = compare(old, new, threshold=0.2)
    assert not regressions


def test_cold_improvement_passes():
    old, new = _bench(10.0, 1.0), _bench(5.0, 1.0)
    _, regressions = compare(old, new, threshold=0.2)
    assert not regressions


def test_unpaired_and_zero_leaves_ignored():
    old = _bench(10.0, 1.0, extra={"old_only_cold_s": 99.0, "zero": 0.0})
    new = _bench(10.0, 1.0, extra={"new_only_cold_s": 99.0, "zero": 0.0})
    rows, regressions = compare(old, new, threshold=0.2)
    assert not regressions
    paths = {p for p, *_ in rows}
    assert not any("only_cold" in p for p in paths)
    assert not any(p.endswith(".zero") for p in paths)


def test_cli_exit_codes(tmp_path, capsys):
    po, pn = tmp_path / "old.json", tmp_path / "new.json"
    po.write_text(json.dumps(_bench(10.0, 1.0)))

    pn.write_text(json.dumps(_bench(10.5, 1.0)))  # +5% cold: within gate
    assert main([str(po), str(pn)]) == 0

    pn.write_text(json.dumps(_bench(15.0, 1.0)))  # +50% cold: regression
    assert main([str(po), str(pn)]) == 1
    assert "REGRESSION" in capsys.readouterr().out

    pn.write_text(json.dumps({"sections": {}}))   # nothing to pair
    assert main([str(po), str(pn)]) == 2
