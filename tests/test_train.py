"""Training substrate: checkpoint/restart exactness, fault injection,
data determinism, compression properties."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypcompat import given, settings, st

from repro.configs.registry import get_smoke_config
from repro.data.pipeline import DataConfig, host_shard, make_batch
from repro.train.checkpoint import Checkpointer
from repro.train.compression import (compress_with_feedback, dequantize_int8,
                                     init_compression_state, quantize_int8,
                                     compressed_psum)
from repro.train.fault_tolerance import (FailureInjector, InjectedFailure,
                                         StepWatchdog, run_with_restarts)
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step

CFG = get_smoke_config("llama3.2-3b")
KEY = jax.random.PRNGKey(0)


def test_data_pipeline_deterministic():
    dc = DataConfig(seq_len=16, global_batch=4, seed=3)
    a = make_batch(CFG, dc, 7)
    b = make_batch(CFG, dc, 7)
    c = make_batch(CFG, dc, 8)
    assert np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_host_shard_partitions_batch():
    dc = DataConfig(seq_len=8, global_batch=8, seed=0)
    b = make_batch(CFG, dc, 0)
    parts = [host_shard(b, i, 4)["tokens"] for i in range(4)]
    stacked = np.concatenate([np.asarray(p) for p in parts])
    assert np.array_equal(stacked, np.asarray(b["tokens"]))


def test_checkpoint_restart_bitwise(tmp_path):
    """Training S steps straight == training with a crash + restore at S/2."""
    dc = DataConfig(seq_len=16, global_batch=4, seed=1)
    opt = AdamWConfig(lr=1e-3, total_steps=8, warmup_steps=1)
    step_fn = jax.jit(make_train_step(CFG, opt))

    def run(n_steps, state):
        for s in range(n_steps):
            state, _ = step_fn(state, make_batch(CFG, dc, s))
        return state

    straight = run(6, init_train_state(CFG, KEY))

    ck = Checkpointer(str(tmp_path / "ck"))
    state = init_train_state(CFG, KEY)
    for s in range(3):
        state, _ = step_fn(state, make_batch(CFG, dc, s))
    ck.save(3, {"params": state.params, "opt": state.opt}, blocking=True)
    # "crash"; restore into a fresh process-like template
    template = init_train_state(CFG, KEY)
    restored = ck.restore(3, {"params": template.params, "opt": template.opt})
    state = template._replace(params=restored["params"], opt=restored["opt"])
    for s in range(3, 6):
        state, _ = step_fn(state, make_batch(CFG, dc, s))

    for a, b in zip(jax.tree.leaves(straight.params), jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpointer_gc_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    state = init_train_state(CFG, KEY)
    for s in (1, 2, 3):
        ck.save(s, {"params": state.params}, blocking=True)
    assert ck.all_steps() == [2, 3]
    assert ck.latest_step() == 3


def test_failure_injection_and_restart():
    calls = []

    inj = FailureInjector(fail_at_steps=(2,))

    def run(start):
        calls.append(start)
        for s in range(0 if start != -1 else 2, 5):
            inj.check(s)
        return 5

    assert run_with_restarts(run, max_restarts=2) == 5
    assert calls == [0, -1]  # one failure, one resume


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(factor=3.0)
    for s in range(10):
        assert not wd.observe(s, 1.0)
    assert wd.observe(10, 10.0)
    assert wd.straggler_steps == [10]


# --- compression -------------------------------------------------------------

@given(st.integers(0, 1000), st.integers(1, 3000))
@settings(max_examples=30, deadline=None)
def test_quantize_roundtrip_bounded(seed, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n) * rng.uniform(0.1, 10), jnp.float32)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s, x.shape)
    err = np.abs(np.asarray(back - x))
    # max error <= scale/2 per chunk
    per_chunk_bound = np.repeat(np.asarray(s) / 2 + 1e-7, 2048)[: n]
    assert (err <= per_chunk_bound + 1e-6).all()


def test_error_feedback_telescopes():
    """Sum of dequantized payloads + final residual == sum of raw grads."""
    rng = np.random.default_rng(0)
    g_total = np.zeros(1000, np.float32)
    sent_total = np.zeros(1000, np.float32)
    residual = jnp.zeros(1000, jnp.float32)
    for step in range(20):
        g = jnp.asarray(rng.standard_normal(1000), jnp.float32)
        g_total += np.asarray(g)
        (q, s), residual = compress_with_feedback(g, residual)
        sent_total += np.asarray(dequantize_int8(q, s, g.shape))
    np.testing.assert_allclose(sent_total + np.asarray(residual), g_total,
                               atol=1e-3)


def test_compressed_psum_mean():
    """Across 4 simulated pods, the compressed mean tracks the true mean."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((4, 512)), jnp.float32)
    res = jnp.zeros((4, 512), jnp.float32)
    out, new_res = jax.vmap(
        lambda gi, ri: compressed_psum(gi, ri, "pods"), axis_name="pods")(g, res)
    true_mean = np.asarray(g).mean(0)
    np.testing.assert_allclose(np.asarray(out[0]), true_mean, atol=0.05)
    # all pods agree on the reduced value
    for i in range(4):
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(out[0]), atol=1e-6)
