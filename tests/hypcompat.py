"""Optional-hypothesis shim.

The container image does not ship ``hypothesis``; a bare module-level
``from hypothesis import ...`` turned every importing test module into a
COLLECTION ERROR, taking all its non-property tests down with it. Import
``given/settings/st`` from here instead: with hypothesis installed the real
objects pass through; without it, ``@given`` marks just the property tests
as skipped and the rest of the module still runs.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*_args, **_kwargs):
        return lambda f: f

    class _StrategyStub:
        """st.<anything>(...).map(...).filter(...) all chain back to the
        stub; only decoration-time use is needed."""

        def __getattr__(self, _name):
            return lambda *a, **k: self

        def __call__(self, *a, **k):
            return self

    st = _StrategyStub()
