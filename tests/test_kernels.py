"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypcompat import given, settings, st

from repro.core import graph as G
from repro.core.hierarchy import Hierarchy
from repro.core.refine import connectivity
from repro.kernels import ops, ref
from repro.kernels.lp_gain import lp_gain_pallas
from repro.kernels.mapcost import mapcost_pallas


def _edge_arrays(n, m, k, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    rows = jnp.asarray(rng.integers(0, n, m), jnp.int32)
    cols = jnp.asarray(rng.integers(0, n, m), jnp.int32)
    w = jnp.asarray(rng.random(m), dtype)
    pe = jnp.asarray(rng.integers(0, k, n), jnp.int32)
    return rows, cols, w, pe


@pytest.mark.parametrize("n,m", [(64, 128), (257, 1000), (1000, 5000), (4096, 2048)])
@pytest.mark.parametrize("hier", [(4, 2), (4, 2, 3), (16, 16)])
def test_mapcost_shapes(n, m, hier):
    h = Hierarchy(a=hier, d=tuple(10.0 ** i for i in range(len(hier))))
    rows, cols, w, pe = _edge_arrays(n, m, h.k, seed=n + m)
    gb = jnp.asarray((1,) + h.strides[:-1], jnp.int32)
    dv = jnp.asarray(h.d, jnp.float32)
    a = ref.mapcost_ref(rows, cols, w, pe, gb, dv)
    b = mapcost_pallas(rows, cols, w, pe, gb, dv, interpret=True)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mapcost_dtypes(dtype):
    h = Hierarchy(a=(4, 4), d=(1.0, 10.0))
    rows, cols, w, pe = _edge_arrays(300, 900, h.k, seed=1, dtype=dtype)
    gb = jnp.asarray((1,) + h.strides[:-1], jnp.int32)
    dv = jnp.asarray(h.d, jnp.float32)
    a = ref.mapcost_ref(rows, cols, w.astype(jnp.float32), pe, gb, dv)
    b = mapcost_pallas(rows, cols, w.astype(jnp.float32), pe, gb, dv, interpret=True)
    np.testing.assert_allclose(float(a), float(b), rtol=2e-3)


@pytest.mark.parametrize("n,deg,k", [(128, 8, 4), (300, 16, 8), (1024, 32, 16), (77, 128, 3)])
def test_lp_gain_shapes(n, deg, k):
    rng = np.random.default_rng(n * k)
    adj = jnp.asarray(rng.integers(0, n + 1, (n, deg)), jnp.int32)  # n == pad
    adw = jnp.asarray(rng.random((n, deg)) * (np.asarray(adj) < n), jnp.float32)
    part = jnp.asarray(rng.integers(0, k, n), jnp.int32)
    c1, b1, g1 = ref.lp_gain_ref(adj, adw, part, k)
    c2, b2, g2 = lp_gain_pallas(adj, adw, part, k, interpret=True)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-4)
    assert np.array_equal(np.asarray(b1), np.asarray(b2))
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_csr_to_ell_roundtrip(seed):
    """ELL conversion preserves per-(row, block) connectivity."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(10, 200))
    g = G.gen_rgg(n, seed=seed)
    k = int(rng.integers(2, 6))
    part = jnp.asarray(rng.integers(0, k, g.N), jnp.int32)
    deg = int(max(np.asarray(G.degrees(g)).max(), 1))
    adj, adw = ref.csr_to_ell(g.rows, g.cols, g.ewgt, g.N, deg)
    conn_ell, _, _ = ref.lp_gain_ref(adj, adw, part, k)
    conn_csr = connectivity(g, part, k)
    np.testing.assert_allclose(np.asarray(conn_ell), np.asarray(conn_csr), atol=1e-4)


def test_ops_dispatch():
    """ops.py returns identical numbers through either backend flag."""
    h = Hierarchy(a=(4, 2), d=(1.0, 10.0))
    rows, cols, w, pe = _edge_arrays(200, 600, h.k, seed=3)
    gb = jnp.asarray((1,) + h.strides[:-1], jnp.int32)
    dv = jnp.asarray(h.d, jnp.float32)
    a = ops.mapcost(rows, cols, w, pe, gb, dv, use_pallas=False)
    b = ops.mapcost(rows, cols, w, pe, gb, dv, use_pallas=True)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-5)


# --- PR3: randomized kernel parity (property-style, seeded loops) -------------

def _rand_hier(rng):
    l = int(rng.integers(2, 4))
    a = tuple(int(rng.integers(2, 5)) for _ in range(l))
    d = tuple(float(10.0 ** i) for i in range(l))
    return Hierarchy(a=a, d=d)


@pytest.mark.parametrize("seed", range(8))
def test_mapcost_parity_random(seed):
    """mapcost_pallas (interpret) == jnp oracle on random edge arrays with
    zero-weight padding tails (the padded-edge case)."""
    rng = np.random.default_rng(seed)
    h = _rand_hier(rng)
    n = int(rng.integers(16, 400))
    m = int(rng.integers(1, 2000))
    rows = rng.integers(0, n, m)
    cols = rng.integers(0, n, m)
    w = rng.random(m).astype(np.float32)
    cut = int(rng.integers(0, m))  # zero-weight tail == padding slots
    w[cut:] = 0.0
    pe = jnp.asarray(rng.integers(0, h.k, n), jnp.int32)
    gb = jnp.asarray((1,) + h.strides[:-1], jnp.int32)
    dv = jnp.asarray(h.d, jnp.float32)
    args = (jnp.asarray(rows, jnp.int32), jnp.asarray(cols, jnp.int32),
            jnp.asarray(w), pe, gb, dv)
    a = ref.mapcost_ref(*args)
    b = mapcost_pallas(*args, interpret=True)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("seed", range(8))
def test_lp_gain_parity_random(seed):
    """lp_gain_pallas (interpret) == jnp oracle on random ELL matrices with
    zero-degree vertices and padded neighbour slots."""
    rng = np.random.default_rng(1000 + seed)
    n = int(rng.integers(8, 300))
    deg = int(rng.integers(1, 24))
    k = int(rng.integers(2, 9))
    adj = rng.integers(0, n + 1, (n, deg))          # n == pad id
    zero_rows = rng.random(n) < 0.2                 # zero-degree vertices
    adj[zero_rows] = n
    adw = rng.random((n, deg)).astype(np.float32) * (adj < n)
    part = jnp.asarray(rng.integers(0, k, n), jnp.int32)
    adj = jnp.asarray(adj, jnp.int32)
    adw = jnp.asarray(adw)
    c1, b1, g1 = ref.lp_gain_ref(adj, adw, part, k)
    c2, b2, g2 = lp_gain_pallas(adj, adw, part, k, interpret=True)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-4)
    assert np.array_equal(np.asarray(b1), np.asarray(b2))
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_ell_adjacency_parity(seed):
    """graph.ell_adjacency == connectivity on non-overflow rows, and the
    overflow mask flags exactly the rows whose degree exceeds the cap."""
    rng = np.random.default_rng(seed)
    g = G.gen_rgg(int(rng.integers(100, 500)), seed=seed)
    k = int(rng.integers(2, 6))
    part = jnp.asarray(rng.integers(0, k, g.N), jnp.int32)
    degs = np.asarray(G.degrees(g))[: int(g.n)]
    for cap in (8, int(max(degs.max(), 1) + 7) // 8 * 8):
        adj, adw, ovf = G.ell_adjacency(g, cap)
        ovf_np = np.asarray(ovf)
        assert np.array_equal(ovf_np[: int(g.n)], degs > cap)
        conn_e, _, _ = ref.lp_gain_ref(adj, adw, part, k)
        conn_c = connectivity(g, part, k)
        keep = ~ovf_np
        np.testing.assert_allclose(np.asarray(conn_e)[keep],
                                   np.asarray(conn_c)[keep], atol=1e-4)


def test_ops_lp_gain_dispatch():
    """ops.lp_gain returns identical numbers through either backend flag."""
    rng = np.random.default_rng(7)
    n, deg, k = 200, 12, 5
    adj = jnp.asarray(rng.integers(0, n + 1, (n, deg)), jnp.int32)
    adw = jnp.asarray(rng.random((n, deg)) * (np.asarray(adj) < n), jnp.float32)
    part = jnp.asarray(rng.integers(0, k, n), jnp.int32)
    c1, b1, g1 = ops.lp_gain(adj, adw, part, k, use_pallas=False)
    c2, b2, g2 = ops.lp_gain(adj, adw, part, k, use_pallas=True)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-4)
    assert np.array_equal(np.asarray(b1), np.asarray(b2))


# --- PR7: gather_rows (device-resident split's data-movement kernel) ----------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
@pytest.mark.parametrize("shape", [(1, 64), (3, 257), (4, 1024), (2, 4096)])
def test_gather_rows_parity(dtype, shape):
    """gather_rows_pallas (interpret) == jnp oracle, bitwise — it is pure
    data movement, so parity must be exact for float AND integer payloads
    (split_blocks gathers weights, ids and relabeled endpoints through it)."""
    from repro.kernels.split import gather_rows_pallas

    K, L = shape
    rng = np.random.default_rng(K * L)
    S = 500
    if dtype == jnp.float32:
        src = jnp.asarray(rng.random(S), jnp.float32)
    else:
        src = jnp.asarray(rng.integers(-100, 100, S), jnp.int32)
    idx = jnp.asarray(rng.integers(0, S + 40, (K, L)), jnp.int32)  # some OOB
    a = ref.gather_rows_ref(src, idx)
    b = gather_rows_pallas(src, idx, interpret=True)
    assert a.dtype == b.dtype == dtype
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_ops_gather_rows_dispatch():
    """ops.gather_rows returns identical values through either backend."""
    rng = np.random.default_rng(9)
    src = jnp.asarray(rng.random(300), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 300, (2, 128)), jnp.int32)
    a = ops.gather_rows(src, idx, use_pallas=False)
    b = ops.gather_rows(src, idx, use_pallas=True)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_split_blocks_backend_invariant(monkeypatch):
    """The on-device split must produce identical children whatever kernel
    backend serves its gathers — it is pure data movement end to end."""
    from repro.core import multisection as M
    from repro.core.graph import split_blocks

    g = G.gen_rgg(200, seed=17)
    rng = np.random.default_rng(1)
    k = 2
    part = jnp.asarray(
        np.where(np.arange(g.N) < int(g.n),
                 rng.integers(0, k, g.N), k).astype(np.int32))
    orig = jnp.asarray(
        np.where(np.arange(g.N) < int(g.n),
                 np.arange(g.N), int(g.n)).astype(np.int32))
    outs = {}
    for be in ("xla", "interpret"):
        monkeypatch.setenv("REPRO_KERNEL_BACKEND", be)
        ch, co, ws = split_blocks(g, part, orig, k, jnp.int32(int(g.n)))
        outs[be] = jax.tree_util.tree_map(np.asarray, (ch, co, ws))
    for a, b in zip(jax.tree_util.tree_leaves(outs["xla"]),
                    jax.tree_util.tree_leaves(outs["interpret"])):
        assert np.array_equal(a, b)


def test_kernel_backend_env(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    assert ops.kernel_backend() == "interpret"
    assert ops.dispatch() == (True, True)
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "xla")
    assert ops.dispatch() == (False, False)
    monkeypatch.delenv("REPRO_KERNEL_BACKEND")
    assert ops.kernel_backend() in ("pallas", "xla")
