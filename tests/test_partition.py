"""Multilevel partitioner: balance, quality sanity, determinism."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import graph as G
from repro.core.coarsen import contract, hem_match
from repro.core.graph import block_weights, edge_cut, edge_mask, vertex_mask
from repro.core.partition import num_levels, partition_host
from repro.core.refine import is_balanced, lp_refine, rebalance


def _check(g, k, eps, preset="fast", salt=0):
    part = partition_host(g, k, eps, preset, salt)
    part_np = np.asarray(part)
    n = int(g.n)
    assert part_np[:n].min() >= 0 and part_np[:n].max() < k
    Lmax = (1 + eps) * float(g.total_weight()) / k
    bw = np.asarray(block_weights(g, part, k))
    assert (bw <= Lmax + 1e-4).all(), f"imbalanced: {bw} vs {Lmax}"
    assert bw.min() > 0, "empty block"
    return part, float(edge_cut(g, part))


def test_grid_quality():
    g = G.gen_grid(24)
    part, cut = _check(g, 4, 0.03, "eco")
    # 24x24 triangulated grid, 4 quadrants: ideal cut ~ 2*24*2=96; LP-based
    # multilevel should land well under a random partition (~ 3/4 * m/2).
    assert cut < 350, cut


def test_rgg_balance_many_k():
    g = G.gen_rgg(3000, seed=1)
    for k in (2, 5, 8, 16):
        _check(g, k, 0.05, "fast", salt=k)


def test_determinism():
    g = G.gen_rgg(1500, seed=2)
    p1, c1 = _check(g, 6, 0.03, "fast", salt=3)
    p2, c2 = _check(g, 6, 0.03, "fast", salt=3)
    assert np.array_equal(np.asarray(p1), np.asarray(p2))
    assert c1 == c2


def test_k1_trivial():
    g = G.gen_grid(8)
    part = partition_host(g, 1, 0.03)
    assert np.asarray(part).max() == 0


def test_weighted_vertices_balance():
    rng = np.random.default_rng(0)
    side = 20
    g0 = G.gen_grid(side)
    vw = rng.integers(1, 10, side * side).astype(np.float64)
    u = np.asarray(g0.rows)[: int(g0.m)]
    v = np.asarray(g0.cols)[: int(g0.m)]
    keep = u < v
    g = G.from_edges(side * side, u[keep], v[keep], vwgt=vw)
    _check(g, 4, 0.05, "eco")


# --- coarsening invariants ---------------------------------------------------

@given(st.integers(0, 1000), st.integers(20, 120))
@settings(max_examples=20, deadline=None)
def test_contract_invariants(seed, n):
    rng = np.random.default_rng(seed)
    m = max(n * 2, 4)
    u = rng.integers(0, n, m)
    v = rng.integers(0, n, m)
    keep = u != v
    if keep.sum() == 0:
        return
    g = G.from_edges(n, u[keep], v[keep])
    labels = hem_match(g, rounds=2, salt=seed % 97)
    gc, newid = contract(g, labels)
    # vertex weight conserved
    assert abs(float(gc.total_weight()) - float(g.total_weight())) < 1e-3
    # edge weight: internal (within-cluster) edges removed, rest conserved
    lab = np.asarray(labels)
    rows = np.asarray(g.rows)[: int(g.m)]
    cols = np.asarray(g.cols)[: int(g.m)]
    w = np.asarray(g.ewgt)[: int(g.m)]
    external = lab[rows] != lab[cols]
    assert abs(float(jnp.sum(gc.ewgt)) - float(w[external].sum())) < 1e-2
    # newid maps real vertices into [0, n_coarse)
    nid = np.asarray(newid)[: int(g.n)]
    assert nid.min() >= 0 and nid.max() < int(gc.n)


def test_matching_is_valid():
    g = G.gen_rgg(800, seed=5)
    labels = np.asarray(hem_match(g, rounds=3, salt=1))
    n = int(g.n)
    for u in range(n):
        l = labels[u]
        assert labels[l] == l, "cluster leader must point to itself"
    # clusters have size <= 2 (matching, not clustering)
    _, counts = np.unique(labels[:n], return_counts=True)
    assert counts.max() <= 2


# --- refinement --------------------------------------------------------------

def test_lp_refine_respects_capacity_and_improves():
    g = G.gen_grid(16)
    k, eps = 4, 0.03
    n = int(g.n)
    rng = np.random.default_rng(0)
    part = jnp.asarray(rng.integers(0, k, g.N), jnp.int32)
    Lmax = (1 + eps) * float(g.total_weight()) / k
    part = rebalance(g, part, k, jnp.float32(Lmax), rounds=8)
    cut0 = float(edge_cut(g, part))
    out = lp_refine(g, part, k, jnp.float32(Lmax), rounds=6)
    cut1 = float(edge_cut(g, out))
    assert is_balanced(g, out, k, Lmax)
    assert cut1 <= cut0 + 1e-6, (cut0, cut1)


def test_rebalance_fixes_overload():
    g = G.gen_grid(12)
    k = 3
    part = jnp.zeros(g.N, jnp.int32)  # everything in block 0
    Lmax = jnp.float32(1.05 * float(g.total_weight()) / k)
    out = rebalance(g, part, k, Lmax, rounds=12)
    assert is_balanced(g, out, k, float(Lmax))


def test_num_levels_monotone():
    assert num_levels(100, 4) <= num_levels(10_000, 4) <= num_levels(1_000_000, 4)
