"""Multilevel partitioner: balance, quality sanity, determinism."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypcompat import given, settings, st

from repro.core import graph as G
from repro.core.coarsen import contract, hem_match
from repro.core.graph import block_weights, edge_cut, edge_mask, vertex_mask
from repro.core.partition import num_levels, partition_host
from repro.core.refine import is_balanced, lp_refine, rebalance


def _check(g, k, eps, preset="fast", salt=0):
    part = partition_host(g, k, eps, preset, salt)
    part_np = np.asarray(part)
    n = int(g.n)
    assert part_np[:n].min() >= 0 and part_np[:n].max() < k
    Lmax = (1 + eps) * float(g.total_weight()) / k
    bw = np.asarray(block_weights(g, part, k))
    assert (bw <= Lmax + 1e-4).all(), f"imbalanced: {bw} vs {Lmax}"
    assert bw.min() > 0, "empty block"
    return part, float(edge_cut(g, part))


def test_grid_quality():
    g = G.gen_grid(24)
    part, cut = _check(g, 4, 0.03, "eco")
    # 24x24 triangulated grid, 4 quadrants: ideal cut ~ 2*24*2=96; LP-based
    # multilevel should land well under a random partition (~ 3/4 * m/2).
    assert cut < 350, cut


def test_rgg_balance_many_k():
    g = G.gen_rgg(3000, seed=1)
    for k in (2, 5, 8, 16):
        _check(g, k, 0.05, "fast", salt=k)


def test_determinism():
    g = G.gen_rgg(1500, seed=2)
    p1, c1 = _check(g, 6, 0.03, "fast", salt=3)
    p2, c2 = _check(g, 6, 0.03, "fast", salt=3)
    assert np.array_equal(np.asarray(p1), np.asarray(p2))
    assert c1 == c2


def test_k1_trivial():
    g = G.gen_grid(8)
    part = partition_host(g, 1, 0.03)
    assert np.asarray(part).max() == 0


def test_weighted_vertices_balance():
    rng = np.random.default_rng(0)
    side = 20
    g0 = G.gen_grid(side)
    vw = rng.integers(1, 10, side * side).astype(np.float64)
    u = np.asarray(g0.rows)[: int(g0.m)]
    v = np.asarray(g0.cols)[: int(g0.m)]
    keep = u < v
    g = G.from_edges(side * side, u[keep], v[keep], vwgt=vw)
    _check(g, 4, 0.05, "eco")


# --- coarsening invariants ---------------------------------------------------

@given(st.integers(0, 1000), st.integers(20, 120))
@settings(max_examples=20, deadline=None)
def test_contract_invariants(seed, n):
    rng = np.random.default_rng(seed)
    m = max(n * 2, 4)
    u = rng.integers(0, n, m)
    v = rng.integers(0, n, m)
    keep = u != v
    if keep.sum() == 0:
        return
    g = G.from_edges(n, u[keep], v[keep])
    labels = hem_match(g, rounds=2, salt=seed % 97)
    gc, newid = contract(g, labels)
    # vertex weight conserved
    assert abs(float(gc.total_weight()) - float(g.total_weight())) < 1e-3
    # edge weight: internal (within-cluster) edges removed, rest conserved
    lab = np.asarray(labels)
    rows = np.asarray(g.rows)[: int(g.m)]
    cols = np.asarray(g.cols)[: int(g.m)]
    w = np.asarray(g.ewgt)[: int(g.m)]
    external = lab[rows] != lab[cols]
    assert abs(float(jnp.sum(gc.ewgt)) - float(w[external].sum())) < 1e-2
    # newid maps real vertices into [0, n_coarse)
    nid = np.asarray(newid)[: int(g.n)]
    assert nid.min() >= 0 and nid.max() < int(gc.n)


def test_contract_indptr_exact_with_zero_padding():
    """Regression: when the coarse graph fills the padded shape
    (n_coarse == N), the dropped edge slots share anchor row N-1 with a
    REAL coarse vertex; the old anchor correction double-subtracted the
    padded-slot count there, corrupting that vertex's indptr row."""
    n = 16
    rng = np.random.default_rng(3)
    u = rng.integers(0, n, 40)
    v = rng.integers(0, n, 40)
    keep = u != v
    # generous edge padding, NO vertex padding (N == n)
    g = G.from_edges(n, u[keep], v[keep], N=n, M=256)
    labels = jnp.arange(n, dtype=jnp.int32)  # identity: n_coarse == N
    gc, _ = contract(g, labels)
    assert int(gc.n) == n
    ind = np.asarray(gc.indptr)
    m_c = int(gc.m)
    assert ind[0] == 0 and ind[-1] == m_c, (ind[-1], m_c)
    assert (np.diff(ind) >= 0).all()
    # row N-1's range holds exactly its own edges
    rows_c = np.asarray(gc.rows)[:m_c]
    assert ind[n] - ind[n - 1] == (rows_c == n - 1).sum()


def test_contract_indptr_tail_with_padding():
    """With vertex padding present, every padding row must have an empty
    indptr range ending at m_coarse (the old correction left
    indptr[N] < m_coarse)."""
    g0 = G.gen_rgg(60, seed=9)
    g = G.pad_graph(g0, 128, 1024)
    labels = hem_match(g, rounds=2, salt=1)
    gc, _ = contract(g, labels)
    ind = np.asarray(gc.indptr)
    assert ind[-1] == int(gc.m)
    assert (np.diff(ind) >= 0).all()
    assert (ind[int(gc.n):] == int(gc.m)).all()


def test_matching_is_valid():
    g = G.gen_rgg(800, seed=5)
    labels = np.asarray(hem_match(g, rounds=3, salt=1))
    n = int(g.n)
    for u in range(n):
        l = labels[u]
        assert labels[l] == l, "cluster leader must point to itself"
    # clusters have size <= 2 (matching, not clustering)
    _, counts = np.unique(labels[:n], return_counts=True)
    assert counts.max() <= 2


# --- refinement --------------------------------------------------------------

def test_lp_refine_respects_capacity_and_improves():
    g = G.gen_grid(16)
    k, eps = 4, 0.03
    n = int(g.n)
    rng = np.random.default_rng(0)
    part = jnp.asarray(rng.integers(0, k, g.N), jnp.int32)
    Lmax = (1 + eps) * float(g.total_weight()) / k
    part = rebalance(g, part, k, jnp.float32(Lmax), rounds=8)
    cut0 = float(edge_cut(g, part))
    out = lp_refine(g, part, k, jnp.float32(Lmax), rounds=6)
    cut1 = float(edge_cut(g, out))
    assert is_balanced(g, out, k, Lmax)
    assert cut1 <= cut0 + 1e-6, (cut0, cut1)


def test_rebalance_fixes_overload():
    g = G.gen_grid(12)
    k = 3
    part = jnp.zeros(g.N, jnp.int32)  # everything in block 0
    Lmax = jnp.float32(1.05 * float(g.total_weight()) / k)
    out = rebalance(g, part, k, Lmax, rounds=12)
    assert is_balanced(g, out, k, float(Lmax))


def test_num_levels_monotone():
    assert num_levels(100, 4) <= num_levels(10_000, 4) <= num_levels(1_000_000, 4)


def test_num_levels_matching_stalls():
    """``max_degree`` shapes the cascade depth (PR 9): a star graph stalls
    matching (one pair per round), so depth collapses to 1 instead of
    paying for levels that cannot shrink; hub-heavy graphs EXTEND depth
    (bounded), and low-degree graphs keep the base schedule."""
    n, k = 10_000, 4
    base = num_levels(n, k)
    assert base > 1
    # star: hub adjacent to all -> shrink ~ n/(n-1) -> stop at one level
    assert num_levels(n, k, max_degree=n - 1) == 1
    # mesh-like: max degree far below n leaves the base schedule intact
    assert num_levels(n, k, max_degree=8) == base
    # hub-heavy: shrink between 1.15x and 1.6x extends depth, but bounded
    hubbed = num_levels(n, k, max_degree=int(n * 0.7))
    assert base < hubbed <= 2 * base + 4
    # degenerate graphs never go below one level
    assert num_levels(200, k, max_degree=199) == 1


def test_partition_host_star_graph():
    """End-to-end: partition_host on a star graph must detect the stall
    from the measured max degree and still return a balanced partition."""
    n = 512
    hub = np.zeros(n - 1, np.int32)
    leaf = np.arange(1, n, dtype=np.int32)
    rows = np.concatenate([hub, leaf])
    cols = np.concatenate([leaf, hub])
    order = np.argsort(rows, kind="stable")
    g = G.assemble_padded(np.ones(n, np.float32), rows[order], cols[order],
                          np.ones(2 * (n - 1), np.float32),
                          n, n, 2 * (n - 1))
    k, eps = 4, 0.05
    part = np.asarray(partition_host(g, k, eps, "fast", salt=1))
    assert set(np.unique(part[:n])) <= set(range(k))
    w = np.bincount(part[:n], minlength=k).astype(float)
    assert w.max() <= (1.0 + eps) * n / k + 1


# --- PR3: kernel-backed refinement (ELL backend) ------------------------------

def test_refine_default_matches_seed_xla_path():
    """On this container (no TPU) backend="auto" must resolve to the seed
    XLA path, so default refinement is bit-identical to backend="xla" —
    edge-cut identical-or-better vs the seed by construction."""
    from repro.core.refine import resolve_backend
    g = G.gen_grid(16)
    k, eps = 4, 0.03
    rng = np.random.default_rng(0)
    part = jnp.asarray(rng.integers(0, k, g.N), jnp.int32)
    Lmax = jnp.float32((1 + eps) * float(g.total_weight()) / k)
    part = rebalance(g, part, k, Lmax, rounds=8)
    out_auto = lp_refine(g, part, k, Lmax, rounds=6, backend="auto")
    out_xla = lp_refine(g, part, k, Lmax, rounds=6, backend="xla")
    if resolve_backend("auto") == "xla":
        assert np.array_equal(np.asarray(out_auto), np.asarray(out_xla))


@pytest.mark.parametrize("gen,arg", [("grid", 16), ("rgg", 1500), ("kron", 9)])
def test_lp_refine_ell_backend_quality(gen, arg):
    """The kernel-backed path stays balanced and never worsens the cut it
    was given. On graphs that fit the degree cap (no overflow rows, i.e.
    the paper's mesh families) it must also land within 5% of the XLA
    path's cut; overflow graphs (kron) freeze their truncated rows, so
    only the safety properties are asserted there."""
    g = {"grid": G.gen_grid, "rgg": G.gen_rgg, "kron": G.gen_kron}[gen](arg)
    k, eps = 4, 0.05
    rng = np.random.default_rng(1)
    part = jnp.asarray(rng.integers(0, k, g.N), jnp.int32)
    Lmax = jnp.float32((1 + eps) * float(g.total_weight()) / k)
    part = rebalance(g, part, k, Lmax, rounds=8, backend="ell")
    assert is_balanced(g, part, k, float(Lmax))
    cut0 = float(G.edge_cut(g, part))
    out_e = lp_refine(g, part, k, Lmax, rounds=6, backend="ell")
    out_x = lp_refine(g, part, k, Lmax, rounds=6, backend="xla")
    cut_e = float(G.edge_cut(g, out_e))
    cut_x = float(G.edge_cut(g, out_x))
    assert is_balanced(g, out_e, k, float(Lmax))
    assert cut_e <= cut0 + 1e-6
    from repro.core.graph import default_ell_deg, ell_adjacency
    _, _, overflow = ell_adjacency(g, default_ell_deg(g.N, g.M))
    if not bool(np.asarray(overflow).any()):
        assert cut_e <= 1.05 * cut_x, (cut_e, cut_x)


def test_partition_ell_backend_valid():
    """Full multilevel partition through the ELL backend: balanced, sane."""
    g = G.gen_rgg(1200, seed=4)
    part = partition_host(g, 6, 0.05, "fast", salt=2, backend="ell")
    n = int(g.n)
    p = np.asarray(part)[:n]
    assert p.min() >= 0 and p.max() < 6
    Lmax = 1.05 * float(g.total_weight()) / 6
    bw = np.asarray(block_weights(g, part, 6))
    assert (bw <= Lmax + 1e-4).all()
    assert bw.min() > 0


def test_admit_threshold_respects_capacity():
    """Direct unit test of the argsort-free admission filter."""
    from repro.core.refine import _admit_by_threshold
    rng = np.random.default_rng(3)
    N, k = 512, 4
    cand = jnp.asarray(rng.random(N) < 0.6)
    best = jnp.asarray(rng.integers(0, k, N), jnp.int32)
    gbest = jnp.asarray(np.round(rng.random(N) * 4), jnp.float32)  # heavy ties
    vw = jnp.asarray(rng.integers(1, 4, N), jnp.float32)
    cap = jnp.asarray([10.0, 25.0, 0.0, 1e9], jnp.float32)
    tie = jnp.asarray(rng.random(N), jnp.float32)
    accept = _admit_by_threshold(cand, best, gbest, vw, cap, k, tie)
    acc = np.asarray(accept)
    assert not np.any(acc & ~np.asarray(cand))
    inflow = np.zeros(k)
    np.add.at(inflow, np.asarray(best)[acc], np.asarray(vw)[acc])
    assert (inflow <= np.asarray(cap) + 1e-4).all(), inflow
    # unconstrained block takes every candidate targeting it
    b3 = np.asarray(cand) & (np.asarray(best) == 3)
    assert np.array_equal(acc[b3], np.full(b3.sum(), True))
