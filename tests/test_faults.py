"""Shared fault injection: determinism, matching modes, trainer back-compat."""
import pytest

from repro.faults import NULL_INJECTOR, FaultInjector, InjectedFault, _hash_uniform
from repro.train.fault_tolerance import FailureInjector, InjectedFailure, run_with_restarts


def test_null_injector_never_fires():
    for i in range(100):
        NULL_INJECTOR.check("dispatch")
        NULL_INJECTOR.check("train_step", index=i)


def test_fail_at_occurrence_fires_once_then_clears():
    """fail_at matches the per-site occurrence counter; each (site, idx)
    fires at most once — a retry of the same seam succeeds (the canonical
    transient fault)."""
    inj = FaultInjector(fail_at={"dispatch": (1,)})
    inj.check("dispatch")                      # occurrence 0: clean
    with pytest.raises(InjectedFault) as ei:
        inj.check("dispatch")                  # occurrence 1: fires
    assert ei.value.site == "dispatch"
    assert ei.value.index == 1
    assert ei.value.transient is True
    inj.check("dispatch")                      # occurrence 2: clean again
    assert inj.fired == [("dispatch", 1)]
    assert inj.count("dispatch") == 3


def test_explicit_index_mode_matches_value_not_counter():
    """index= overrides the counter (the trainer's step-indexed mode)."""
    inj = FaultInjector(fail_at={"train_step": (7,)})
    inj.check("train_step", index=3)
    with pytest.raises(InjectedFault):
        inj.check("train_step", index=7)
    inj.check("train_step", index=7)  # once per (site, idx): retry succeeds
    assert inj.fired == [("train_step", 7)]


def test_sites_are_independent():
    inj = FaultInjector(fail_at={"cache": (0,)})
    inj.check("dispatch")  # other sites untouched by the cache plan
    with pytest.raises(InjectedFault):
        inj.check("cache")
    inj.check("finalize")


def test_rate_mode_is_deterministic_across_instances():
    """The rate draws are a pure function of (seed, site, count): two
    injectors with the same plan fire on exactly the same occurrences,
    regardless of interleaving."""
    def pattern(seed):
        inj = FaultInjector(seed=seed, rates={"dispatch": 0.3})
        fired = []
        for i in range(200):
            try:
                inj.check("dispatch")
            except InjectedFault:
                fired.append(i)
        return fired

    a, b = pattern(seed=5), pattern(seed=5)
    assert a == b
    assert 20 < len(a) < 120  # ~30% of 200, loose bounds
    assert pattern(seed=6) != a  # seed actually enters the draw


def test_hash_uniform_range_and_stability():
    vals = [_hash_uniform(0, "s", i) for i in range(100)]
    assert all(0.0 <= v < 1.0 for v in vals)
    assert vals == [_hash_uniform(0, "s", i) for i in range(100)]


def test_transient_flag_and_custom_error_type():
    class BoomError(InjectedFault):
        pass

    inj = FaultInjector(fail_at={"x": (0,)}, transient=False,
                        error_type=BoomError)
    with pytest.raises(BoomError) as ei:
        inj.check("x")
    assert ei.value.transient is False


def test_trainer_injector_back_compat():
    """train.FailureInjector keeps its step-indexed API and fired set on
    top of the shared injector; InjectedFailure is-a InjectedFault so the
    service's retry classifier treats trainer faults uniformly."""
    assert issubclass(InjectedFailure, InjectedFault)
    inj = FailureInjector(fail_at_steps=(2, 4))
    for step in (0, 1):
        inj.check(step)
    with pytest.raises(InjectedFailure):
        inj.check(2)
    inj.check(2)  # fires once per step
    with pytest.raises(InjectedFailure):
        inj.check(4)
    assert inj.fired == {2, 4}


def test_run_with_restarts_survives_injected_failures():
    inj = FailureInjector(fail_at_steps=(3,))
    state = {"step": 0, "runs": 0}

    def run_fn(start_step):
        state["runs"] += 1
        while state["step"] < 6:
            inj.check(state["step"])
            state["step"] += 1
        return state["step"]

    assert run_with_restarts(run_fn, max_restarts=2) == 6
    assert state["runs"] == 2
    assert inj.fired == {3}
