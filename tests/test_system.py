"""End-to-end behaviour tests for the whole system: the paper's algorithm
driving the production launcher, training with failure injection, and the
serving engine — the integration seams between subsystems."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_smoke_config
from repro.core import Hierarchy, SharedMapConfig, shared_map
from repro.core import graph as G
from repro.core.mapping import evaluate_J
from repro.data.pipeline import DataConfig, make_batch
from repro.models import model as M
from repro.serve.engine import Engine
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


def test_sharedmap_end_to_end_quality_and_balance():
    """The headline behaviour: high-quality eps-balanced mappings."""
    g = G.gen_rgg(3000, seed=11)
    h = Hierarchy(a=(4, 8), d=(1.0, 10.0))
    res = shared_map(g, h, SharedMapConfig(eps=0.03, preset="eco"))
    bw = np.bincount(res.pe_of, minlength=h.k)
    Lmax = 1.03 * int(g.n) / h.k
    assert (bw <= Lmax + 1e-6).all()
    # random baseline is far worse
    rng = np.random.default_rng(0)
    j_rand = evaluate_J(g, h, rng.integers(0, h.k, int(g.n)))
    assert res.J < 0.3 * j_rand


def test_training_loss_decreases():
    """A small model actually learns the pipeline's bigram structure."""
    cfg = get_smoke_config("llama3.2-3b")
    dc = DataConfig(seq_len=64, global_batch=8, seed=0)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3, total_steps=40,
                                                    warmup_steps=4)))
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    first, last = None, None
    for s in range(30):
        state, m = step(state, make_batch(cfg, dc, s))
        loss = float(m["loss"])
        first = first if first is not None else loss
        last = loss
    assert last < first - 0.2, (first, last)


def test_serving_engine_generates():
    cfg = get_smoke_config("llama3.2-3b")
    params = M.init_fn(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=48)
    prompts = np.ones((2, 4), np.int32)
    out, stats = eng.generate(prompts, steps=8)
    assert out.shape == (2, 8)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
    assert stats.tokens == 16


def test_serving_engine_sampling_path():
    """temperature > 0 routes decode through jax.random.categorical; must
    be deterministic per seed and in-vocab."""
    cfg = get_smoke_config("llama3.2-3b")
    params = M.init_fn(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=48)
    prompts = np.ones((2, 4), np.int32)
    out_a, stats = eng.generate(prompts, steps=8, temperature=0.8, seed=3)
    out_b, _ = eng.generate(prompts, steps=8, temperature=0.8, seed=3)
    out_c, _ = eng.generate(prompts, steps=8, temperature=0.8, seed=4)
    assert out_a.shape == (2, 8)
    assert (out_a >= 0).all() and (out_a < cfg.vocab_size).all()
    assert stats.tokens == 16
    assert np.array_equal(out_a, out_b), "same seed must reproduce"
    assert not np.array_equal(out_a, out_c), "different seed should differ"


def test_train_driver_with_failure_injection(tmp_path, capsys):
    """The full launcher path: crash at step 12, auto-restart, finish."""
    from repro.launch.train import main as train_main
    train_main(["--arch", "llama3.2-3b", "--smoke", "--steps", "16",
                "--batch", "2", "--seq", "32", "--fail-at", "12",
                "--checkpoint-every", "5", "--log-every", "100",
                "--checkpoint-dir", str(tmp_path / "ck")])
    out = capsys.readouterr().out
    assert "[restart #1]" in out
    assert "[restore] resumed from step" in out
    assert "[done]" in out
