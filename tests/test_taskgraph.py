"""Workload ingestion layer (PR 10): TaskGraph normalization, validation,
fingerprint stability, CSR lowering, and end-to-end equivalence of the
TaskGraph route with the raw-Graph route (direct call AND via the mapping
service, where the cache keys on the TaskGraph fingerprint)."""
import subprocess
import sys

import numpy as np
import pytest

from repro.core import graph as G
from repro.core.api import SharedMapConfig, shared_map, shared_map_direct
from repro.core.hierarchy import Hierarchy
from repro.core.taskgraph import TaskGraph

H = Hierarchy(a=(4, 2), d=(1.0, 10.0))
CFG = SharedMapConfig(preset="fast")


# ------------------------------------------------------------ normalization


def test_normalization_canonical_form():
    # raw: a self-loop, a duplicate (in both directions), unsorted order
    tg = TaskGraph.from_edges(
        4,
        u=[2, 1, 0, 3, 0, 2],
        v=[2, 0, 1, 1, 2, 0],
        w=[9.0, 2.0, 3.0, 4.0, 1.0, 6.0])
    # self-loop (2,2) dropped; {0,1} coalesced to 2+3=5; {0,2} to 1+6=7
    assert tg.n == 4 and tg.m == 3
    assert tg.u.tolist() == [0, 0, 1]
    assert tg.v.tolist() == [1, 2, 3]
    assert tg.w.tolist() == [5.0, 7.0, 4.0]
    assert np.all(tg.u < tg.v)


def test_zero_weight_edges_dropped_and_default_weights():
    tg = TaskGraph.from_edges(3, [0, 1], [1, 2], [0.0, 2.0])
    assert tg.m == 1 and tg.w.tolist() == [2.0]
    tg1 = TaskGraph.from_edges(3, [0, 1], [1, 2])  # w defaults to ones
    assert tg1.w.tolist() == [1.0, 1.0]
    assert tg1.vwgt.tolist() == [1.0, 1.0, 1.0]


def test_from_coo_sums_both_directions():
    # directed traffic matrix: 3 bytes u->v plus 4 bytes v->u = 7 undirected
    tg = TaskGraph.from_coo(2, rows=[0, 1], cols=[1, 0], vals=[3.0, 4.0])
    assert tg.m == 1 and tg.w.tolist() == [7.0]


def test_dtypes_are_device_currency():
    tg = TaskGraph.from_edges(3, [0], [1], [2.5], vwgt=[1.0, 2.0, 3.0])
    assert tg.u.dtype == np.int32 and tg.v.dtype == np.int32
    assert tg.w.dtype == np.float32 and tg.vwgt.dtype == np.float32


# -------------------------------------------------------------- validation


@pytest.mark.parametrize("kwargs,msg", [
    (dict(n=0, u=[], v=[]), "n >= 1"),
    (dict(n=2, u=[0], v=[2]), "out of range"),
    (dict(n=2, u=[0], v=[-1]), "out of range"),
    (dict(n=2, u=[0], v=[1], w=[-1.0]), "non-negative"),
    (dict(n=2, u=[0], v=[1], w=[float("nan")]), "finite"),
    (dict(n=2, u=[0, 1], v=[1]), "differ in length"),
    (dict(n=2, u=[0], v=[1], w=[1.0, 2.0]), "does not match"),
    (dict(n=2, u=[0], v=[1], vwgt=[1.0]), "does not match"),
    (dict(n=2, u=[0], v=[1], vwgt=[1.0, float("inf")]), "finite"),
])
def test_builder_rejects_malformed(kwargs, msg):
    with pytest.raises(ValueError, match=msg):
        TaskGraph.from_edges(**kwargs)


# ------------------------------------------------------------- fingerprint


def test_fingerprint_invariant_to_edge_order_and_direction():
    u = np.array([0, 1, 2, 0, 3])
    v = np.array([1, 2, 3, 2, 4])
    w = np.array([1.0, 2.0, 3.0, 4.0, 5.0])
    base = TaskGraph.from_edges(5, u, v, w)
    rng = np.random.default_rng(0)
    for _ in range(3):
        p = rng.permutation(u.size)
        flip = rng.random(u.size) < 0.5  # swap direction of random edges
        uu = np.where(flip, v, u)[p]
        vv = np.where(flip, u, v)[p]
        other = TaskGraph.from_edges(5, uu, vv, w[p])
        assert other.fingerprint() == base.fingerprint()


def test_fingerprint_sensitive_to_content():
    base = TaskGraph.from_edges(4, [0, 1], [1, 2], [1.0, 2.0])
    for other in (
        TaskGraph.from_edges(5, [0, 1], [1, 2], [1.0, 2.0]),   # n
        TaskGraph.from_edges(4, [0, 1], [1, 3], [1.0, 2.0]),   # topology
        TaskGraph.from_edges(4, [0, 1], [1, 2], [1.0, 2.5]),   # edge weight
        TaskGraph.from_edges(4, [0, 1], [1, 2], [1.0, 2.0],
                             vwgt=[2, 1, 1, 1]),               # vertex weight
    ):
        assert other.fingerprint() != base.fingerprint()


def test_fingerprint_ignores_meta():
    a = TaskGraph.from_edges(3, [0], [1], [1.0], meta={"source": "x"})
    b = TaskGraph.from_edges(3, [0], [1], [1.0], meta={"source": "y", "z": 1})
    assert a.fingerprint() == b.fingerprint()


def test_fingerprint_deterministic_across_processes():
    code = (
        "from repro.core.taskgraph import TaskGraph\n"
        "tg = TaskGraph.from_edges(5, [3, 0, 1], [1, 1, 2], [2.0, 1.0, 4.0],\n"
        "                          vwgt=[1, 2, 3, 4, 5])\n"
        "print(tg.fingerprint().hex())\n"
    )
    digests = {
        subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, check=True).stdout.strip()
        for _ in range(2)
    }
    here = TaskGraph.from_edges(5, [3, 0, 1], [1, 1, 2], [2.0, 1.0, 4.0],
                                vwgt=[1, 2, 3, 4, 5]).fingerprint().hex()
    assert digests == {here}


# ------------------------------------------------------------ CSR lowering


def test_to_graph_csr_invariants_and_cache():
    tg = TaskGraph.from_edges(6, [0, 1, 2, 4], [1, 2, 3, 5], [1.0, 2, 3, 4])
    g = tg.to_graph()
    assert int(g.n) == 6 and int(g.m) == 2 * tg.m  # each edge stored twice
    m = int(g.m)
    # total CSR weight mass is exactly twice the undirected mass
    assert float(np.asarray(g.ewgt)[:m].sum()) == \
        pytest.approx(2 * tg.total_edge_weight())
    assert tg.to_graph() is g  # default-padding lowering is memoized
    g2 = tg.to_graph(N=64, M=64)  # explicit padding bypasses the memo
    assert int(g2.N) == 64 and int(g2.n) == 6


def test_from_graph_roundtrip_preserves_fingerprint():
    g = G.gen_rgg(500, seed=3)
    tg = TaskGraph.from_graph(g)
    rt = TaskGraph.from_graph(tg.to_graph())
    assert rt.fingerprint() == tg.fingerprint()
    assert rt.m == tg.m and rt.n == tg.n


# ------------------------------------------- end-to-end route equivalence


def test_shared_map_taskgraph_bit_identical_to_graph():
    g = G.gen_rgg(400, seed=7)
    tg = TaskGraph.from_graph(g)
    via_tg = shared_map(tg, H, CFG)
    via_g = shared_map(tg.to_graph(), H, CFG)
    assert np.array_equal(via_tg.pe_of, via_g.pe_of)
    assert via_tg.J == via_g.J


def test_service_taskgraph_bit_identical_and_cached():
    from repro.serve.mapper import MappingService
    g = G.gen_rgg(400, seed=8)
    tg = TaskGraph.from_graph(g)
    # the direct baseline runs on the CANONICAL CSR (normalization may
    # reorder the generator's edge slots; the contract is TaskGraph-route
    # == Graph-route for the same canonical graph)
    direct = shared_map_direct(tg.to_graph(), H, CFG)
    svc = MappingService()
    try:
        r1 = svc.map(tg, H, CFG)
        assert np.array_equal(r1.pe_of, direct.pe_of) and r1.J == direct.J
        assert not r1.stats["result_cache"]["hit"]
        # repeat submit is served from the fingerprint-keyed cache
        r2 = svc.map(tg, H, CFG)
        assert r2.stats["result_cache"]["hit"]
        assert np.array_equal(r2.pe_of, direct.pe_of)
        # a rebuilt TaskGraph (same content, different object/edge order)
        # hits the same cache entry: the key is the content fingerprint
        m = tg.m
        perm = np.random.default_rng(0).permutation(m)
        tg2 = TaskGraph.from_edges(tg.n, tg.v.astype(np.int64)[perm],
                                   tg.u.astype(np.int64)[perm], tg.w[perm],
                                   vwgt=tg.vwgt)
        assert tg2.fingerprint() == tg.fingerprint()
        r3 = svc.map(tg2, H, CFG)
        assert r3.stats["result_cache"]["hit"]
        assert np.array_equal(r3.pe_of, direct.pe_of)
    finally:
        svc.close()
