import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see ONE
# device; only launch/dryrun.py forces 512 host devices (see system design).


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration tests")
