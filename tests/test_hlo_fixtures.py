"""Hand-written HLO fixtures for the parsing layer (PR 10 satellite).

The jax-compiled tests in test_mesh_and_hlo.py cover whatever HLO the
installed XLA happens to emit; these fixtures pin the parser's behaviour on
the syntax variants we must keep handling: tuple-typed ops, operands with
inlined types, empty operand lists, nested `while` multipliers, fusion-body
dot attribution — for both ``analyze_hlo`` and ``extract_comm_graph``.
"""
import warnings

import pytest

from repro.launch.comm_graph import extract_comm_graph
from repro.launch.hlo_analysis import (Op, _dot_flops, _operands,
                                       _shape_bytes, analyze_hlo,
                                       parse_computations)

# One `while` around a 4x4 matmul body; the loop state is a tuple
# (f32[4,4], s32[]) — 64 + 4 = 68 bytes.
WHILE_HLO = """\
HloModule fixture_while

%body (p.1: (f32[4,4], s32[])) -> (f32[4,4], s32[]) {
  %p.1 = (f32[4,4], s32[]) parameter(0)
  %g0 = f32[4,4] get-tuple-element(%p.1), index=0
  %g1 = s32[] get-tuple-element(%p.1), index=1
  %mm = f32[4,4] dot(%g0, %g0), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ni = s32[] add(%g1, %one)
  ROOT %t = (f32[4,4], s32[]) tuple(%mm, %ni)
}

%cond (p.2: (f32[4,4], s32[])) -> pred[] {
  %p.2 = (f32[4,4], s32[]) parameter(0)
  %i = s32[] get-tuple-element(%p.2), index=1
  %lim = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %lim), direction=LT
}

ENTRY %main (a: f32[4,4]) -> (f32[4,4], s32[]) {
  %a = f32[4,4] parameter(0)
  %z = s32[] constant(0)
  %init = (f32[4,4], s32[]) tuple(%a, %z)
  ROOT %wh = (f32[4,4], s32[]) while(%init), condition=%cond, body=%body
}
"""


def test_while_fixture_parses_tuple_typed_ops():
    comps = parse_computations(WHILE_HLO)
    assert set(comps) == {"body", "cond", "main"}
    assert comps["main"].is_entry
    wh = comps["main"].ops[-1]
    assert wh.kind == "while" and wh.type_str.startswith("(")
    assert _shape_bytes(wh.type_str) == 68  # 4*4*f32 + s32


def test_while_fixture_comm_graph_structure():
    tg = extract_comm_graph(WHILE_HLO, trip_hints=[5])
    # tasks in parse order: mm=0, ni=1 (body), lt=2 (cond), wh=3 (entry)
    assert tg.n == 4 and tg.m == 3
    assert tg.meta["while_trips"] == [5]
    assert not tg.meta["hints_exhausted"]
    edges = {(int(a), int(b)): float(w)
             for a, b, w in zip(tg.u, tg.v, tg.w)}
    # boundary edges: 68 output bytes x 5 trips, split over the two body
    # roots (mm, ni); the cond root keeps the full 340
    assert edges == {(0, 3): 170.0, (1, 3): 170.0, (2, 3): 340.0}
    # the dot runs 5 times: vwgt = 5 * (2 * 16 * 4); FLOP-free tasks floor at 1
    assert tg.vwgt.tolist() == [640.0, 1.0, 1.0, 1.0]


def test_while_fixture_analyze_hlo_agrees():
    an = analyze_hlo(WHILE_HLO, trip_hints=[5])
    assert an.flops == 5 * 2 * 16 * 4
    assert an.while_trips == [5] and not an.hints_exhausted


# `while` in a `while`: hints consumed in nesting order, multipliers multiply.
NESTED_HLO = """\
HloModule fixture_nested

%ibody (p.1: (f32[2,2], s32[])) -> (f32[2,2], s32[]) {
  %p.1 = (f32[2,2], s32[]) parameter(0)
  %g = f32[2,2] get-tuple-element(%p.1), index=0
  %i = s32[] get-tuple-element(%p.1), index=1
  %d = f32[2,2] dot(%g, %g), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %c1 = s32[] constant(1)
  %j = s32[] add(%i, %c1)
  ROOT %t.1 = (f32[2,2], s32[]) tuple(%d, %j)
}

%icond (p.2: (f32[2,2], s32[])) -> pred[] {
  %p.2 = (f32[2,2], s32[]) parameter(0)
  %i.2 = s32[] get-tuple-element(%p.2), index=1
  %lim.1 = s32[] constant(5)
  ROOT %lt.1 = pred[] compare(%i.2, %lim.1), direction=LT
}

%obody (p.3: (f32[2,2], s32[])) -> (f32[2,2], s32[]) {
  %p.3 = (f32[2,2], s32[]) parameter(0)
  ROOT %w2 = (f32[2,2], s32[]) while(%p.3), condition=%icond, body=%ibody
}

%ocond (p.4: (f32[2,2], s32[])) -> pred[] {
  %p.4 = (f32[2,2], s32[]) parameter(0)
  %i.4 = s32[] get-tuple-element(%p.4), index=1
  %lim.2 = s32[] constant(3)
  ROOT %lt.2 = pred[] compare(%i.4, %lim.2), direction=LT
}

ENTRY %main (a: f32[2,2]) -> (f32[2,2], s32[]) {
  %a = f32[2,2] parameter(0)
  %z = s32[] constant(0)
  %init = (f32[2,2], s32[]) tuple(%a, %z)
  ROOT %w1 = (f32[2,2], s32[]) while(%init), condition=%ocond, body=%obody
}
"""


def test_nested_while_multipliers_multiply():
    an = analyze_hlo(NESTED_HLO, trip_hints=[3, 5])
    # the inner dot (2*4*2 = 16 flops) runs 3 * 5 times
    assert an.flops == 3 * 5 * 16
    assert an.while_trips == [3, 5]
    tg = extract_comm_graph(NESTED_HLO, trip_hints=[3, 5])
    # the dot task carries the multiplied compute weight
    assert float(tg.vwgt.max()) == 3 * 5 * 16


def test_hints_exhausted_flag_and_warning():
    # two `while` ops, one hint: the last hint is reused and flagged
    with pytest.warns(UserWarning, match="2 `while` ops but only 1"):
        an = analyze_hlo(NESTED_HLO, trip_hints=[3])
    assert an.hints_exhausted and an.while_hints_needed == 2
    assert an.while_trips == [3, 3]
    assert an.flops == 3 * 3 * 16
    tg = extract_comm_graph(NESTED_HLO, trip_hints=[3])
    assert tg.meta["hints_exhausted"]
    # no hints at all: trips default to 1 — still flagged as a guess, but
    # silently (an explicit "I have no hints" caller shouldn't be nagged)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        an0 = analyze_hlo(NESTED_HLO)
    assert an0.hints_exhausted and an0.while_hints_needed == 2
    # exact hints: flag off, no warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        an2 = analyze_hlo(NESTED_HLO, trip_hints=[3, 5])
    assert not an2.hints_exhausted


# A collective whose payload is distributed over its replica group.
COLLECTIVE_HLO = """\
HloModule fixture_collective

%sum (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %add.1 = f32[] add(%x, %y)
}

ENTRY %main (a: f32[64]) -> f32[64] {
  %a = f32[64] parameter(0)
  %sq = f32[64] multiply(%a, %a)
  %ar = f32[64] all-reduce(%sq), replica_groups={{0,1,2,3}}, to_apply=%sum
  ROOT %out = f32[64] add(%ar, %ar)
}
"""


def test_collective_bytes_distributed_over_group():
    tg = extract_comm_graph(COLLECTIVE_HLO)
    # tasks in parse order: add.1 (reducer body) = 0, sq = 1, ar = 2, out = 3.
    # The reducer's scalar add stays an ISOLATED task — all-reduce bodies are
    # applied element-wise inside the collective, not a dataflow boundary.
    assert tg.n == 4
    edges = {(int(a), int(b)): float(w)
             for a, b, w in zip(tg.u, tg.v, tg.w)}
    # sq -> ar: 256 dataflow bytes + 256/4 per-shard collective share
    # ar -> out: consumed twice at 256 bytes each
    assert edges == {(1, 2): 256.0 + 64.0, (2, 3): 512.0}
    an = analyze_hlo(COLLECTIVE_HLO)
    assert an.collective_bytes == {"all-reduce": 256.0}
    assert an.num_collectives == {"all-reduce": 1}


# Fusion with a dot in its body: the fusion op absorbs the body's FLOPs at
# fused granularity; op granularity expands the body into its own task.
FUSION_HLO = """\
HloModule fixture_fusion

%fused_dot (fa: f32[8,8], fb: f32[8,8]) -> f32[8,8] {
  %fa = f32[8,8] parameter(0)
  %fb = f32[8,8] parameter(1)
  ROOT %fd = f32[8,8] dot(%fa, %fb), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8] parameter(0)
  %e = f32[8,8] exponential(%x)
  ROOT %f = f32[8,8] fusion(%e, %x), kind=kOutput, calls=%fused_dot
}
"""


def test_fusion_body_dot_attribution():
    dot_flops = 2 * 64 * 8
    an = analyze_hlo(FUSION_HLO)
    assert an.flops == dot_flops

    fused = extract_comm_graph(FUSION_HLO)  # tasks: e=0, f=1
    assert fused.n == 2 and fused.meta["granularity"] == "fused"
    assert fused.vwgt.tolist() == [1.0, float(dot_flops)]

    op = extract_comm_graph(FUSION_HLO, granularity="op")
    # body expands: fd=0 (body parses first), e=1, f=2; the dot's weight
    # moves to the body task, and a boundary edge fd—f appears
    assert op.n == 3
    assert op.vwgt.tolist() == [float(dot_flops), 1.0, 1.0]
    edges = {(int(a), int(b)): float(w) for a, b, w in zip(op.u, op.v, op.w)}
    assert edges == {(0, 2): 256.0, (1, 2): 256.0}


def test_min_tasks_escalates_granularity():
    assert extract_comm_graph(FUSION_HLO, min_tasks=3).meta["granularity"] \
        == "op"
    assert extract_comm_graph(FUSION_HLO, min_tasks=2).meta["granularity"] \
        == "fused"
    with pytest.raises(ValueError, match="granularity"):
        extract_comm_graph(FUSION_HLO, granularity="bogus")


# ------------------------------------------------------- parser unit tests


def test_operands_with_inlined_types():
    op = Op("add.2", "f32[8]{0}", "add",
            "  %add.2 = f32[8]{0} add(f32[8]{0} %a, f32[8]{0} %b)")
    assert _operands(op) == ["a", "b"]


def test_operands_empty_list():
    op = Op("tok", "token[]", "after-all", "  %tok = token[] after-all()")
    assert _operands(op) == []


def test_operands_tuple_typed_depth_aware_split():
    op = Op("t", "(f32[4,4], s32[])", "tuple",
            "  ROOT %t = (f32[4,4], s32[]) tuple(f32[4,4]{1,0} %mm, s32[] %ni)")
    assert _operands(op) == ["mm", "ni"]


def test_dot_flops_exact():
    shapes = {"lhs": "f32[128,256]", "rhs": "f32[256,512]"}
    op = Op("d", "f32[128,512]", "dot",
            "  %d = f32[128,512] dot(%lhs, %rhs), "
            "lhs_contracting_dims={1}, rhs_contracting_dims={0}")
    assert _dot_flops(op, shapes) == 2 * 128 * 512 * 256
