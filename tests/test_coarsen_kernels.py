"""Coarsening kernels vs jnp oracles, and backend invariance of the cascade.

The coarsening path's contract is BITWISE parity across pallas / interpret
/ xla (kernels/ref.py shares the row bodies), so every comparison here is
array_equal, not allclose.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import graph as G
from repro.core.coarsen import (coarsen_cascade, coarsen_once, contract_ell,
                                hem_match, hem_match_ell)
from repro.core.graph import assemble_padded, default_ell_deg, ell_adjacency
from repro.core.partition import clear_batched_partition_cache
from repro.kernels import ops, ref
from repro.kernels.coarsen_kernels import (contract_edges_pallas,
                                           hem_propose_pallas)


def _rand_ell(rng, n, deg, zero_rows=0.2, self_loops=0.1):
    """Random padded ELL adjacency with zero-degree rows and self-loops."""
    adj = rng.integers(0, n + 1, (n, deg))          # n == pad id
    if zero_rows:
        adj[rng.random(n) < zero_rows] = n          # zero-degree vertices
    if self_loops:
        rows = np.nonzero(rng.random(n) < self_loops)[0]
        adj[rows, rng.integers(0, deg, rows.shape[0])] = rows  # self-loops
    adw = rng.random((n, deg)).astype(np.float32) * (adj < n)
    return jnp.asarray(adj, jnp.int32), jnp.asarray(adw)


# --- hem_propose: kernel (interpret) == oracle, bitwise ----------------------

@pytest.mark.parametrize("seed", range(6))
def test_hem_propose_parity_random(seed):
    """Zero-degree rows, self-loops, partially matched vectors — and sizes
    straddling the tile boundary so padded lanes are exercised."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 600))                    # < and > TILE_V=256
    deg = int(rng.integers(1, 24))
    adj, adw = _rand_ell(rng, n, deg)
    jit_ = jnp.asarray(rng.random((n, deg)), jnp.float32)
    matched = jnp.asarray((rng.random(n) < 0.3).astype(np.int32))
    a = ref.hem_propose_ref(adj, adw, jit_, matched)
    b = hem_propose_pallas(adj, adw, jit_, matched, interpret=True)
    assert a.dtype == b.dtype == jnp.int32
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_hem_propose_fully_matched():
    """A fully-matched graph proposes nothing (sentinel N everywhere)."""
    rng = np.random.default_rng(0)
    n, deg = 300, 8
    adj, adw = _rand_ell(rng, n, deg, zero_rows=0.0)
    jit_ = jnp.asarray(rng.random((n, deg)), jnp.float32)
    matched = jnp.ones((n,), jnp.int32)
    a = ref.hem_propose_ref(adj, adw, jit_, matched)
    b = hem_propose_pallas(adj, adw, jit_, matched, interpret=True)
    assert np.all(np.asarray(a) == n)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_hem_propose_padded_lanes_inert():
    """Tile padding must not leak into real rows: n == TILE_V + 1 forces a
    nearly-empty last tile; every real row still matches the oracle."""
    rng = np.random.default_rng(3)
    n, deg = 257, 8
    adj, adw = _rand_ell(rng, n, deg, zero_rows=0.0, self_loops=0.0)
    jit_ = jnp.asarray(rng.random((n, deg)), jnp.float32)
    matched = jnp.zeros((n,), jnp.int32)
    a = ref.hem_propose_ref(adj, adw, jit_, matched)
    b = hem_propose_pallas(adj, adw, jit_, matched, interpret=True)
    assert np.array_equal(np.asarray(a), np.asarray(b))


# --- contract_edges: kernel (interpret) == oracle, bitwise -------------------

@pytest.mark.parametrize("seed", range(6))
def test_contract_edges_parity_random(seed):
    """Duplicate ids in a row must accumulate bitwise-identically (fixed
    add chain), distinct counts and first-slot placement must agree."""
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(8, 600))
    d2 = int(rng.integers(2, 32))
    # few distinct ids per row -> many duplicates to accumulate
    cand = rng.integers(0, max(n // 8, 2), (n, d2))
    cand[rng.random((n, d2)) < 0.3] = n             # invalid slots
    candw = rng.random((n, d2)).astype(np.float32) * (cand < n)
    cand = jnp.asarray(cand, jnp.int32)
    candw = jnp.asarray(candw)
    a = ref.contract_edges_ref(cand, candw, n)
    b = contract_edges_pallas(cand, candw, interpret=True)
    for xa, xb in zip(a, b):
        assert xa.dtype == xb.dtype
        assert np.array_equal(np.asarray(xa), np.asarray(xb))


def test_ops_coarsen_dispatch():
    """ops wrappers return identical values through either backend flag."""
    rng = np.random.default_rng(9)
    n, deg = 200, 8
    adj, adw = _rand_ell(rng, n, deg)
    jit_ = jnp.asarray(rng.random((n, deg)), jnp.float32)
    matched = jnp.zeros((n,), jnp.int32)
    a = ops.hem_propose(adj, adw, jit_, matched, use_pallas=False)
    b = ops.hem_propose(adj, adw, jit_, matched, use_pallas=True)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    cand = jnp.asarray(rng.integers(0, n + 1, (n, 2 * deg)), jnp.int32)
    candw = jnp.asarray(
        rng.random((n, 2 * deg)).astype(np.float32) * (np.asarray(cand) < n))
    for xa, xb in zip(ops.contract_edges(cand, candw, use_pallas=False),
                      ops.contract_edges(cand, candw, use_pallas=True)):
        assert np.array_equal(np.asarray(xa), np.asarray(xb))


# --- the ELL coarsening path: invariants + backend invariance ----------------

def _check_coarse_invariants(g, gc, newid):
    """Structural invariants the v-cycle relies on (note: total EDGE weight
    is conserved only without ELL overflow; vertex weight always is)."""
    N = g.N
    n, nc = int(g.n), int(gc.n)
    mc = int(gc.m)
    newid_np = np.asarray(newid)
    assert 0 < nc <= n
    assert np.all((newid_np[:n] >= 0) & (newid_np[:n] < nc))
    np.testing.assert_allclose(float(jnp.sum(gc.vwgt)),
                               float(jnp.sum(g.vwgt)), rtol=1e-5)
    rows = np.asarray(gc.rows)
    cols = np.asarray(gc.cols)
    ind = np.asarray(gc.indptr)
    assert ind[0] == 0 and ind[-1] == mc == ind[nc]
    assert np.all(np.diff(rows[:mc]) >= 0)           # sorted rows
    counts = np.bincount(rows[:mc], minlength=N)
    assert np.array_equal(np.cumsum(counts)[:N], ind[1:])
    assert np.all(rows[:mc] != cols[:mc])            # no self-loops
    assert np.all(np.asarray(gc.ewgt)[:mc] > 0)
    assert np.all(np.asarray(gc.ewgt)[mc:] == 0)


@pytest.mark.parametrize("seed", [0, 1, 5])
def test_coarsen_ell_invariants(seed):
    g = G.gen_rgg(400, seed=seed)
    deg = default_ell_deg(int(g.n), int(g.m))
    gc, newid = coarsen_once(g, salt=seed, ell_deg=deg)
    _check_coarse_invariants(g, gc, newid)
    # matching validity: clusters have size <= 2 (HEM matches pairs)
    lab = np.asarray(newid)[: int(g.n)]
    assert np.bincount(lab).max() <= 2


def test_coarsen_ell_overflow_rows():
    """Rows past the DEG cap are truncated but the result is still a valid
    coarse graph (heuristic-only contract; cut is evaluated on the fine
    graph elsewhere)."""
    g = G.gen_rgg(300, seed=2)
    assert int(np.asarray(G.degrees(g))[: int(g.n)].max()) > 4
    gc, newid = coarsen_once(g, salt=1, ell_deg=8)   # cap below max degree
    _check_coarse_invariants(g, gc, newid)


def test_coarsen_ell_matches_segment_weightsum():
    """Without overflow the ELL path conserves total edge weight exactly,
    like the segment path (different matchings, same invariant)."""
    g = G.gen_rgg(300, seed=4)
    deg = int(np.asarray(G.degrees(g))[: int(g.n)].max())
    deg = (deg + 7) // 8 * 8
    gc, _ = coarsen_once(g, salt=3, ell_deg=deg)
    # contracted intra-pair weight + coarse weight == fine weight
    fine_w = float(jnp.sum(g.ewgt))
    coarse_w = float(jnp.sum(gc.ewgt))
    adj, adw, _ = ell_adjacency(g, deg)
    labels = hem_match_ell(g, adj, adw, salt=3)
    # each matched pair removes its (directed) intra edges from the total
    rows_np, cols_np = np.asarray(g.rows), np.asarray(g.cols)
    lab_np = np.asarray(labels)
    gone = (lab_np[rows_np] == lab_np[cols_np]) & (np.asarray(g.ewgt) > 0)
    np.testing.assert_allclose(
        coarse_w, fine_w - float(np.asarray(g.ewgt)[gone].sum()), rtol=1e-5)


def _flip_backend(monkeypatch, be):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", be)
    jax.clear_caches()
    clear_batched_partition_cache()


def test_coarsen_backend_invariant(monkeypatch):
    """coarsen_once + coarsen_cascade produce bit-identical coarse graphs
    under xla and interpret dispatch (trace-time env, hence cache clears)."""
    g = G.gen_rgg(500, seed=11)
    deg = default_ell_deg(int(g.n), int(g.m))
    outs = {}
    for be in ("xla", "interpret"):
        _flip_backend(monkeypatch, be)
        gc, newid = coarsen_once(g, salt=5, ell_deg=deg)
        ns, ms = coarsen_cascade(g, 3, ell_deg=deg)
        outs[be] = jax.tree_util.tree_map(np.asarray, (gc, newid, ns, ms))
    for a, b in zip(jax.tree_util.tree_leaves(outs["xla"]),
                    jax.tree_util.tree_leaves(outs["interpret"])):
        assert a.dtype == b.dtype and np.array_equal(a, b)


@pytest.mark.parametrize("preset", ["fast", "eco", "strong"])
def test_partition_backend_invariant_presets(monkeypatch, preset):
    """The fused v-cycle's final partition is bitwise backend-invariant for
    every preset. Refinement is pinned to its kernel-free CSR path
    (backend="xla" — the ELL lp_gain kernel is allclose-, not bitwise-,
    parity), so the env flip exercises ONLY the coarsening kernels."""
    from repro.core.partition import partition_host
    g = G.gen_rgg(250, seed=21)
    outs = {}
    for be in ("xla", "interpret"):
        _flip_backend(monkeypatch, be)
        outs[be] = np.asarray(
            partition_host(g, 4, 0.05, preset, salt=3, backend="xla"))
    assert np.array_equal(outs["xla"], outs["interpret"])


@pytest.mark.parametrize("strategy", ["device", "bucket", "layer"])
def test_multisection_backend_invariant_strategies(monkeypatch, strategy):
    """End-to-end hierarchical multisection is bitwise backend-invariant
    for every scheduling strategy (the coarsening + split kernels flip;
    refinement pinned to the CSR path as above)."""
    from repro.core.hierarchy import Hierarchy
    from repro.core.multisection import hierarchical_multisection
    g = G.gen_rgg(220, seed=31)
    h = Hierarchy(a=(2, 2), d=(1.0, 10.0))
    outs = {}
    for be in ("xla", "interpret"):
        _flip_backend(monkeypatch, be)
        res = hierarchical_multisection(g, h, eps=0.05, preset="fast",
                                        strategy=strategy, seed=2,
                                        backend="xla")
        outs[be] = np.asarray(res.pe_of)
    assert np.array_equal(outs["xla"], outs["interpret"])


# --- satellite 1: round-salt regression --------------------------------------

def _cycle_graph(n):
    """Unit-weight n-cycle: all scores tie, so matching is pure jitter."""
    u = np.arange(n, dtype=np.int32)
    v = (u + 1) % n
    rows = np.concatenate([u, v]).astype(np.int32)
    cols = np.concatenate([v, u]).astype(np.int32)
    order = np.argsort(rows, kind="stable")  # Graph invariant: sorted rows
    w = np.ones(2 * n, np.float32)
    return assemble_padded(np.ones(n, np.float32), rows[order], cols[order],
                           w, n, n, 2 * n)


@pytest.mark.parametrize("matcher", ["segment", "ell"])
def test_round_salt_breaks_proposal_cycles(matcher):
    """A round whose proposals form a cycle matches nothing; with the old
    round-invariant jitter the SAME proposals repeated every round, so
    rounds 2..r were dead weight. The fix re-salts per round: some salt
    that stalls at rounds=1 must match a pair by rounds=3."""
    g = _cycle_graph(6)
    deg = 8
    adj, adw, _ = ell_adjacency(g, deg)

    def match(rounds, salt):
        if matcher == "segment":
            labels = hem_match(g, rounds=rounds, salt=salt)
        else:
            labels = hem_match_ell(g, adj, adw, rounds=rounds, salt=salt)
        lab = np.asarray(labels)[: int(g.n)]
        return int((lab != np.arange(int(g.n))).sum()) // 2  # matched pairs

    stalled = [s for s in range(200) if match(1, s) == 0]
    assert stalled, "no salt produced a fully cyclic first round (test graph too easy)"
    recovered = sum(1 for s in stalled if match(3, s) >= 1)
    # the re-salted rounds must rescue the overwhelming majority of stalls
    assert recovered >= len(stalled) * 3 // 4, (recovered, len(stalled))


def test_coarsen_cascade_telemetry_shapes():
    ns, ms = coarsen_cascade(G.gen_rgg(400, seed=1), 4)
    ns, ms = np.asarray(ns), np.asarray(ms)
    assert ns.shape == ms.shape == (4,)
    assert np.all(np.diff(ns) <= 0) and ns[-1] >= 1   # monotone shrink
