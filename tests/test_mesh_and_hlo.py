"""Mesh construction, SharedMap device ordering, HLO analyzer correctness.

These run with the default single-device backend: mesh construction itself
is exercised end-to-end by launch/dryrun.py (which forces 512 host devices
in a separate process — see tests/test_dryrun_integration.py)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.mapping import evaluate_J
from repro.launch.hlo_analysis import analyze_hlo, parse_computations
from repro.launch.mesh import logical_comm_graph, physical_hierarchy


def test_logical_comm_graph_shapes():
    # logical_comm_graph now returns a workload-layer TaskGraph (PR 10)
    tg1 = logical_comm_graph(False)
    tg2 = logical_comm_graph(True)
    assert tg1.n == 256 and tg2.n == 512
    assert tg1.meta["source"] == "logical_mesh"
    # multi-pod graph has pod-crossing edges
    assert float(tg2.w.sum()) > float(tg1.w.sum())
    # lowering to CSR doubles the undirected edge weight mass
    g1 = tg1.to_graph()
    assert int(g1.n) == 256
    assert float(np.asarray(g1.ewgt)[:int(g1.m)].sum()) == \
        pytest.approx(2 * float(tg1.w.sum()))


def test_sharedmap_order_improves_over_random():
    """The integration claim: SharedMap's device order has J <= a random
    permutation's J on the physical hierarchy."""
    from repro.launch.mesh import sharedmap_device_order
    g = logical_comm_graph(False).to_graph()
    h = physical_hierarchy(False)
    perm = sharedmap_device_order(False)
    assert sorted(perm.tolist()) == list(range(256))  # a bijection
    j_sm = evaluate_J(g, h, perm)
    rng = np.random.default_rng(0)
    j_rand = np.mean([evaluate_J(g, h, rng.permutation(256)) for _ in range(5)])
    assert j_sm < j_rand, (j_sm, j_rand)


# --- HLO analyzer ------------------------------------------------------------

def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_analyzer_counts_dot_flops():
    A = jnp.zeros((128, 256), jnp.float32)
    B = jnp.zeros((256, 512), jnp.float32)
    comp = _compile(lambda a, b: a @ b, A, B)
    an = analyze_hlo(comp.as_text())
    expect = 2 * 128 * 256 * 512
    assert abs(an.flops - expect) / expect < 0.05, (an.flops, expect)


def test_analyzer_scales_scan_bodies():
    L = 7

    def f(x, w):
        def body(c, wl):
            return jnp.tanh(c @ wl), ()
        c, _ = jax.lax.scan(body, x, w)
        return c

    x = jnp.zeros((32, 64), jnp.float32)
    w = jnp.zeros((L, 64, 64), jnp.float32)
    comp = _compile(f, x, w)
    an = analyze_hlo(comp.as_text(), trip_hints=[L])
    expect = L * 2 * 32 * 64 * 64
    assert abs(an.flops - expect) / expect < 0.05, (an.flops, expect)
    assert an.while_trips == [L]


def test_analyzer_nested_scans_multiply():
    Lo, Li = 3, 5

    def f(x, w):
        def outer(c, wl):
            def inner(ci, _):
                return jnp.tanh(ci @ wl), ()
            ci, _ = jax.lax.scan(inner, c, None, length=Li)
            return ci, ()
        c, _ = jax.lax.scan(outer, x, w)
        return c

    x = jnp.zeros((16, 32), jnp.float32)
    w = jnp.zeros((Lo, 32, 32), jnp.float32)
    comp = _compile(f, x, w)
    an = analyze_hlo(comp.as_text(), trip_hints=[Lo, Li])
    expect = Lo * Li * 2 * 16 * 32 * 32
    assert abs(an.flops - expect) / expect < 0.05, (an.flops, expect)


def test_analyzer_parses_computations():
    comp = _compile(lambda a: (a @ a).sum(), jnp.zeros((64, 64)))
    comps = parse_computations(comp.as_text())
    assert any(c.is_entry for c in comps.values())
    kinds = {op.kind for c in comps.values() for op in c.ops}
    assert "dot" in kinds or "fusion" in kinds
