"""Mapping-as-a-service: concurrent requests, coalesced dispatch, caching,
and the PR6 robustness layer (deadlines, overload shedding, degradation).

    PYTHONPATH=src python examples/serve_mapping.py

Simulates a burst of mapping traffic (distinct communication graphs on a
deep hierarchy, plus one hot repeat) against a MappingService and prints
the coalescing and cache telemetry next to the sequential baseline; then
saturates a deliberately tiny service to show load shedding, deadlines,
and the tracker's view of it all.
"""
import asyncio
import time

import numpy as np

from repro.core import graph as G
from repro.core.api import SharedMapConfig, shared_map, shared_map_direct
from repro.core.hierarchy import Hierarchy
from repro.serve.admission import DeadlineExceededError, ServiceOverloadError
from repro.serve.mapper import MappingService
from repro.serve.tracker import InMemoryTracker


async def traffic(svc: MappingService, gs, h, cfg):
    """A burst of concurrent requests (the asyncio front of the service)."""
    return await asyncio.gather(*(svc.amap(g, h, cfg) for g in gs))


def main():
    h = Hierarchy(a=(2, 2, 2, 2), d=(1.0, 5.0, 10.0, 100.0))  # 16 PEs
    cfg = SharedMapConfig(preset="fast")
    gs = [G.gen_rgg(64, seed=100 + i) for i in range(8)]

    # sequential baseline (direct path, warmed by a first sweep)
    for g in gs:
        shared_map_direct(g, h, cfg)
    t0 = time.time()
    direct = [shared_map_direct(g, h, cfg) for g in gs]
    seq_s = time.time() - t0

    # throughput service: cache off so the repeat burst measures compute
    svc = MappingService(cache_entries=0)
    t0 = time.time()
    asyncio.run(traffic(svc, gs, h, cfg))
    cold_s = time.time() - t0  # pays the merged-batch-width compiles once
    t0 = time.time()
    served = asyncio.run(traffic(svc, gs, h, cfg))
    warm_s = time.time() - t0  # steady state: what sustained traffic sees

    for d, r in zip(direct, served):
        assert np.array_equal(d.pe_of, r.pe_of), "service must be bit-identical"
    co = svc.stats()["coalesce"]
    svc.close()

    # caching service: a hot repeat is answered from the result cache, and
    # plain shared_map routes through it while installed
    cache_svc = MappingService()
    with cache_svc.installed():
        shared_map(gs[0], h, cfg)
        t0 = time.time()
        rep = shared_map(gs[0], h, cfg)
        hit_s = time.time() - t0
    assert rep.stats["result_cache"]["hit"]
    cache_svc.close()

    print(f"burst of {len(gs)}: sequential {seq_s*1e3:.0f}ms, "
          f"service cold {cold_s*1e3:.0f}ms (compiles merged widths), "
          f"steady {warm_s*1e3:.0f}ms ({seq_s/warm_s:.2f}x)")
    print(f"coalesced {co['groups']} groups into {co['dispatches']} dispatches "
          f"({co['members']} member partitions)")
    print(f"cached repeat: {hit_s*1e6:.0f}us "
          f"(J={rep.J:.0f}, identical to first answer)")

    # --- overload-safe serving: bounds, deadlines, tracker -----------------
    # Tiny bounds so this demo saturates; production bounds are sized to
    # the host. A tracker streams admission/shed/cache counters (swap
    # InMemoryTracker for JsonlTracker("mapper.jsonl") to keep a file).
    tracker = InMemoryTracker()
    svc = MappingService(max_inflight=1, max_queue=2, tracker=tracker)
    try:
        # a request that cannot wait: deadline_s cancels it wherever it is
        # (queued, or between multisection levels) once the budget is spent
        urgent = svc.submit(gs[0], h, cfg, priority=5, deadline_s=30.0)

        # a burst past the bounds: overflow is shed with a typed error (not
        # silently queued), admitted requests complete normally
        futs = svc.submit_many([(g, h, cfg) for g in gs])
        outcomes = {"ok": 0, "shed": 0, "deadline": 0}
        for f in [urgent] + futs:
            try:
                f.result(timeout=600)
                outcomes["ok"] += 1
            except ServiceOverloadError:
                outcomes["shed"] += 1   # back off and retry elsewhere
            except DeadlineExceededError:
                outcomes["deadline"] += 1
        adm = svc.stats()["admission"]
    finally:
        svc.close()
    print(f"overloaded burst: {outcomes['ok']} served, {outcomes['shed']} "
          f"shed, {outcomes['deadline']} past deadline "
          f"(queue bound {2}, inflight bound {1})")
    print(f"tracker counters: " + ", ".join(
        f"{k}={v}" for k, v in sorted(tracker.counters.items())
        if k.startswith("service.")))
    assert adm["shed"] == outcomes["shed"]


if __name__ == "__main__":
    main()
