"""Quickstart: map a task graph onto a supercomputer hierarchy with SharedMap.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import graph as G
from repro.core.api import SharedMapConfig, shared_map
from repro.core.baselines import identity_mapping, random_mapping
from repro.core.hierarchy import Hierarchy
from repro.core.mapping import evaluate_J


def main():
    # A sparse communication graph: 6,000 tasks from a random-geometric
    # pattern (typical of domain-decomposed scientific codes).
    g = G.gen_rgg(6_000, seed=0)
    print(f"communication graph: n={int(g.n)} m={int(g.m)//2} undirected edges")

    # The machine: 4 PEs/processor, 2 processors/node, 3 nodes (paper Fig 1)
    h = Hierarchy(a=(4, 2, 3), d=(1.0, 10.0, 100.0))
    print(f"hierarchy {h} -> k={h.k} PEs")

    for strategy in ("naive", "bucket"):
        res = shared_map(g, h, SharedMapConfig(
            eps=0.03, preset="eco", strategy=strategy, seed=0))
        bw = np.bincount(res.pe_of, minlength=h.k)
        print(f"[{strategy:6s}] J = {res.J:12.0f}   "
              f"balance max/avg = {bw.max() / bw.mean():.3f}   "
              f"partition calls = {res.stats['partition_calls']}   "
              f"time = {res.stats['seconds']:.1f}s")

    print(f"[random] J = {evaluate_J(g, h, random_mapping(g, h)):12.0f}")
    print(f"[identy] J = {evaluate_J(g, h, identity_mapping(g, h)):12.0f}")


if __name__ == "__main__":
    main()
