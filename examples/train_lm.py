"""End-to-end training driver: a ~20M-param llama-family model for a few
hundred steps on CPU (scale --layers/--batch up on real hardware; the same
driver lowers the full 72B configs in the multi-pod dry-run).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import sys

from repro.launch.train import main as train_main


def run():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    args = ap.parse_args()
    argv = ["--arch", "llama3.2-3b", "--smoke", "--layers", "4",
            "--steps", str(args.steps), "--batch", str(args.batch),
            "--seq", str(args.seq), "--checkpoint-every", "50",
            "--checkpoint-dir", "ckpts/train_lm"]
    for f in args.fail_at:
        argv += ["--fail-at", str(f)]
    train_main(argv)


if __name__ == "__main__":
    run()
