"""Batched serving with KV caches (greedy + temperature sampling).

    PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np
import jax

from repro.configs.registry import get_smoke_config
from repro.models import model as M
from repro.serve.engine import Engine


def main():
    cfg = get_smoke_config("mixtral-8x22b")  # MoE + sliding window
    params = M.init_fn(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=96)
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab_size, (4, 8)).astype(np.int32)
    out, stats = eng.generate(prompts, steps=32, temperature=0.8)
    print(f"arch={cfg.name} batch={prompts.shape[0]}")
    print(f"prefill: {stats.prefill_s:.2f}s  decode: {stats.decode_s:.2f}s "
          f"({stats.tok_per_s:.0f} tok/s)")
    print("sample tokens:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
