"""Map a real model's communication graph onto the chip hierarchy.

The PR 10 closed loop, end to end: compile one tiny train cell of a model
from ``configs/`` (abstract params — no weights materialize), extract its
per-op HLO communication graph as a :class:`TaskGraph`, run SharedMap on
the physical 16x16 chip hierarchy, and compare the communication cost J
against a launcher that ignores the communication pattern entirely.

    PYTHONPATH=src python examples/map_model.py [arch]

Any ``configs/`` arch works; the default (whisper-tiny) finishes in about
a minute on one CPU core.
"""
import sys
import time

from repro.core.api import SharedMapConfig, shared_map
from repro.core.mapping import evaluate_J
from repro.launch.comm_graph import default_placement, model_comm_graph
from repro.launch.mesh import physical_hierarchy


def main(arch: str = "whisper-tiny"):
    h = physical_hierarchy(False)  # 16 chips/rack x 16 racks, D = 1:10
    print(f"hierarchy {h} -> k={h.k} chips")

    # 1. compile + extract (min_tasks=2k auto-expands fusion groups until
    #    the graph is fine-grained enough to spread over k chips)
    t0 = time.time()
    tg = model_comm_graph(arch, min_tasks=2 * h.k)
    print(f"extracted {tg!r} in {time.time() - t0:.1f}s "
          f"(granularity={tg.meta['granularity']}, "
          f"while_trips={tg.meta['while_trips']})")

    # 2. map
    t0 = time.time()
    res = shared_map(tg, h, SharedMapConfig(preset="fast"))
    print(f"mapped in {time.time() - t0:.1f}s "
          f"({res.stats['partition_calls']} partition calls)")

    # 3. score against program-order chunking onto the default chip order
    g = tg.to_graph()
    j_def = evaluate_J(g, h, default_placement(tg.n, h.k))
    print(f"J(sharedmap) = {res.J:12.4g}")
    print(f"J(default)   = {j_def:12.4g}   "
          f"-> {j_def / res.J:.2f}x less cross-hierarchy traffic")


if __name__ == "__main__":
    main(*sys.argv[1:2])
