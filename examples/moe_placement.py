"""SharedMap as an MoE expert-placement engine.

Expert-to-expert token co-activation forms a communication graph; placing
co-activated experts on nearby chips cuts cross-rack/pod all-to-all volume.

    PYTHONPATH=src python examples/moe_placement.py
"""
import numpy as np

from repro.core import graph as G
from repro.core.api import SharedMapConfig, shared_map
from repro.core.hierarchy import Hierarchy
from repro.core.mapping import evaluate_J


def main():
    rng = np.random.default_rng(0)
    E = 64  # moonshot-style expert count
    # synthetic co-activation: block-structured (experts specialize by topic)
    blocks = 8
    C = rng.random((E, E)) * 0.1
    for b in range(blocks):
        s = slice(b * E // blocks, (b + 1) * E // blocks)
        C[s, s] += rng.random((E // blocks, E // blocks))
    C = np.triu(C, 1)
    u, v = np.nonzero(C)
    g = G.from_edges(E, u, v, C[u, v])

    # place 64 experts over 4 racks x 16 chips (weight: tokens/pair)
    h = Hierarchy(a=(16, 4), d=(1.0, 10.0))
    res = shared_map(g, h, SharedMapConfig(eps=0.25, preset="eco", seed=0))

    rng2 = np.random.default_rng(1)
    naive = (np.arange(E) * h.k) // E
    rand_J = np.mean([evaluate_J(g, h, rng2.permutation(h.k)[(np.arange(E)*h.k)//E])
                      for _ in range(5)])
    print(f"experts={E} chips={h.k}  ({h})")
    print(f"sharedmap placement J = {res.J:10.1f}")
    print(f"naive block placement J = {evaluate_J(g, h, naive):10.1f}")
    print(f"random placement     J = {rand_J:10.1f}")
    cross = res.J / evaluate_J(g, h, naive)
    print(f"-> cross-rack traffic at {cross:.2f}x of naive placement")


if __name__ == "__main__":
    main()
